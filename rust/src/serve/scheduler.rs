//! Continuous batching: requests join and leave the active decode
//! batch at *step* granularity, not request granularity.
//!
//! Each `step()`:
//!   1. admits queued sessions into free KV slots up to `max_batch`
//!      (prefill + first sampled token happen at admission, so TTFT is
//!      measured through the same path a real server would take);
//!   2. optionally stalls sessions (client-disconnect injection for the
//!      synthetic workload);
//!   3. runs one decode step for every active session — a single
//!      fused GEMM batch on the native backend (`Engine::step_batch`),
//!      per-session forwards on the artifact backend; the batch
//!      shrinks the moment a session finishes and grows the moment a
//!      queued one is admitted;
//!   4. retires finished sessions (slot freed immediately — the next
//!      step can hand it to a queued request);
//!   5. TTL-evicts stalled sessions whose slots have been idle too
//!      long.

use crate::obs::hist::Hist;
use crate::obs::span::{SpanOutcome, Tracer};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::serve::admission::{AdmissionPolicy, Brownout, BrownoutConfig,
                              Decision, RejectReason};
use crate::serve::engine::{sample_token, BatchReq, Engine};
use crate::serve::faults::{FaultPlan, FaultPoint};
use crate::serve::kv_cache::{CompactMode, CompactReport, KvCachePool};
use crate::serve::session::{SessionState, SessionTable};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Aggregate counters the serve report is built from.
#[derive(Default, Debug, Clone)]
pub struct SchedStats {
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// rejection breakdown by `RejectReason`
    pub rejected_queue_full: usize,
    pub rejected_too_long: usize,
    pub rejected_malformed: usize,
    pub completed: usize,
    /// total abnormal exits (every non-`Done` terminal), of which the
    /// three counters below are disjoint sub-buckets (plain TTL /
    /// preemption evictions are `evicted` minus their sum)
    pub evicted: usize,
    /// sessions cancelled because their per-request deadline expired
    pub deadline_exceeded: usize,
    /// sessions quarantined after a per-session engine-step failure
    pub quarantined: usize,
    /// sessions whose client went away mid-generation
    pub disconnects: usize,
    /// decode steps that had at least one active session (total steps
    /// live on `Scheduler::step_no()` — not duplicated here)
    pub busy_steps: u64,
    pub occupancy_sum: u64,
    pub max_occupancy: usize,
    pub prefill_tokens: u64,
    pub generated_tokens: u64,
}

impl SchedStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.busy_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / self.busy_steps as f64
    }

    pub fn rejection_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.submitted as f64
    }
}

pub struct Scheduler {
    pub pool: KvCachePool,
    pub admission: AdmissionPolicy,
    pub table: SessionTable,
    queue: VecDeque<u64>,
    active: Vec<u64>,
    stalled: Vec<u64>,
    pub max_batch: usize,
    pub ttl_steps: u64,
    step_no: u64,
    pub stats: SchedStats,
    /// end-to-end request latency (submit → last token), log2-bucket
    /// histogram: O(1) record on the hot path, bounded memory
    pub latency: Hist,
    /// time-to-first-token (submit → first sampled token)
    pub ttft: Hist,
    /// inter-token latency: one sample per decoded token per session,
    /// measured scheduler-side so batching waits are included
    pub itl: Hist,
    /// optional request-lifecycle tracer (installed by the workload
    /// driver when `--trace-out` / `--events-out` is requested)
    tracer: Option<Tracer>,
    /// reusable request buffer for the batched decode step (avoids a
    /// fresh Vec per step on the hot path)
    reqs_buf: Vec<BatchReq>,
    /// seeded fault injection (`--fault-plan`); `None` keeps every
    /// injection site a single never-taken branch
    faults: Option<FaultPlan>,
    /// process-wide default deadline applied to submits that carry none
    default_deadline_ms: Option<u64>,
    /// at least one live-or-past session carried a deadline — gates the
    /// per-step sweep so deadline-free serving pays nothing
    has_deadlines: bool,
    /// load-shedding degradation state machine (disabled by default)
    pub brownout: Brownout,
}

impl Scheduler {
    pub fn new(pool: KvCachePool, admission: AdmissionPolicy,
               max_batch: usize, ttl_steps: u64) -> Scheduler {
        assert!(max_batch > 0);
        Scheduler {
            pool,
            admission,
            table: SessionTable::new(),
            queue: VecDeque::new(),
            active: Vec::new(),
            stalled: Vec::new(),
            max_batch,
            ttl_steps,
            step_no: 0,
            stats: SchedStats::default(),
            latency: Hist::new(),
            ttft: Hist::new(),
            itl: Hist::new(),
            tracer: None,
            reqs_buf: Vec::new(),
            faults: None,
            default_deadline_ms: None,
            has_deadlines: false,
            brownout: Brownout::new(None),
        }
    }

    /// Install a parsed fault plan (`--fault-plan`). Injection starts
    /// with the next `step`/`submit`.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Consult the plan at one injection point (false when no plan).
    /// Public so the server front-end can drive the points that live
    /// outside the scheduler (artifact reload corruption).
    pub fn fire_fault(&mut self, point: FaultPoint) -> bool {
        match self.faults.as_mut() {
            Some(f) => f.fire(point),
            None => false,
        }
    }

    /// Default per-request deadline for submits that don't carry one.
    pub fn set_default_deadline_ms(&mut self, ms: Option<u64>) {
        self.default_deadline_ms = ms;
    }

    /// Enable (or disable) brownout load shedding.
    pub fn set_brownout(&mut self, cfg: Option<BrownoutConfig>) {
        self.brownout = Brownout::new(cfg);
    }

    /// `Retry-After` hint for shed requests: the admission policy's
    /// queue-occupancy hint plus the brownout penalty while degraded.
    pub fn retry_after_secs(&self, queue_len: usize) -> u64 {
        self.admission.retry_after_secs(queue_len)
            + self.brownout.retry_after_bump()
    }

    /// Install a lifecycle tracer. Spans are recorded from the next
    /// `submit` on; sessions already in flight are not traced.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Remove and return the tracer (export time).
    pub fn take_tracer(&mut self) -> Option<Tracer> {
        self.tracer.take()
    }

    /// Submit one request. Returns the session id when admitted to the
    /// queue, `None` when admission rejected it (counted in stats).
    pub fn submit(&mut self, client: usize, prompt: Vec<i32>,
                  max_new: usize, seed: u64, temperature: f32)
                  -> Option<u64> {
        self.submit_req(client, prompt, max_new, seed, temperature, None)
    }

    /// `submit` with a per-request deadline override (milliseconds from
    /// now; `None` inherits the process default from `--deadline-ms`).
    #[allow(clippy::too_many_arguments)]
    pub fn submit_req(&mut self, client: usize, prompt: Vec<i32>,
                      max_new: usize, seed: u64, temperature: f32,
                      deadline_ms: Option<u64>)
                      -> Option<u64> {
        self.stats.submitted += 1;
        // brownout degradation: admit, but with a clamped generation
        // budget (deterministic — brownout state advances in step space)
        let max_new = self.brownout.clamp_max_new(max_new);
        let deadline_ms = deadline_ms.or(self.default_deadline_ms);
        match self.admission.decide(prompt.len(), max_new,
                                    self.queue.len()) {
            Decision::Reject(reason) => {
                self.stats.rejected += 1;
                match reason {
                    RejectReason::QueueFull => {
                        self.stats.rejected_queue_full += 1;
                    }
                    RejectReason::TooLong => {
                        self.stats.rejected_too_long += 1;
                    }
                    RejectReason::Malformed => {
                        self.stats.rejected_malformed += 1;
                    }
                }
                None
            }
            Decision::Admit => {
                self.stats.admitted += 1;
                let prompt_len = prompt.len();
                let id = self.table.create(
                    client,
                    prompt,
                    max_new,
                    SessionState::Queued,
                    self.step_no,
                    seed,
                    temperature,
                    deadline_ms,
                );
                self.has_deadlines |= deadline_ms.is_some();
                self.queue.push_back(id);
                // span uses the session's own submit instant so span
                // deltas equal the recorded TTFT exactly
                let t = self.table.get(id).submitted_at;
                if let Some(tr) = self.tracer.as_mut() {
                    tr.on_submit(id, client, prompt_len, t);
                }
                Some(id)
            }
        }
    }

    /// Cancel a live session: remove it from whichever list holds it
    /// and take the Evicted exit, so its KV slot frees immediately and
    /// its span closes. Returns false for unknown or already-terminal
    /// sessions (idempotent — the server calls this on any sink error,
    /// racing completion).
    pub fn cancel(&mut self, id: u64) -> bool {
        self.cancel_as(id, SpanOutcome::Evicted)
    }

    /// `cancel` with an explicit exit reason (the server uses
    /// `Disconnected` when a streaming socket goes away mid-SSE).
    pub fn cancel_as(&mut self, id: u64, outcome: SpanOutcome) -> bool {
        if !self.table.contains(id) || self.table.get(id).is_terminal()
        {
            return false;
        }
        self.queue.retain(|&x| x != id);
        self.active.retain(|&x| x != id);
        self.stalled.retain(|&x| x != id);
        self.terminate(id, outcome);
        true
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn step_no(&self) -> u64 {
        self.step_no
    }

    /// No queued, active, or stalled work left.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
            && self.stalled.is_empty()
    }

    /// One decode step of the whole server. `stall_prob` injects
    /// client-stall events (0.0 disables).
    pub fn step(&mut self, engine: &Engine, rt: &mut Runtime,
                workload_rng: &mut Rng, stall_prob: f64) -> Result<()> {
        self.step_no += 1;

        // 0. injected core-loop stall (exercises the server watchdog)
        if let Some(f) = self.faults.as_mut() {
            if f.fire(FaultPoint::Stall) {
                std::thread::sleep(f.stall());
            }
        }

        // 0b. deadline sweep: expired sessions exit with their partial
        // tokens before this step does any work on them
        if self.has_deadlines {
            self.sweep_deadlines();
        }

        // 0c. threshold-triggered compaction: when fragmentation (dead
        // pages + stranded tail slack) crosses the configured
        // fraction, migrate and sweep before admitting. Compaction
        // moves bytes verbatim and never touches live token payloads,
        // so interleaving it with decode steps keeps logits
        // bit-identical to the slab oracle.
        if let CompactMode::Thresh(p) = self.pool.compact_mode() {
            if self.pool.frag_frac() >= p {
                self.run_compaction();
            }
        }

        // 1. admit: fill free slots, up to the batch cap. On the
        // paged layout `KvCachePool::admit` also maps published prefix
        // pages into the new session's table (prefill resumes past the
        // shared span) and gates on page availability, so a session is
        // only admitted when its whole prompt can be faulted in.
        let native = engine.is_native();
        let mut compacted_on_starve = false;
        while self.active.len() < self.max_batch {
            let Some(&front) = self.queue.front() else { break };
            let (prompt, temperature) = {
                let s = self.table.get(front);
                (s.prompt.clone(), s.temperature)
            };
            // prefix reuse requires a backend that actually writes the
            // native KV cache; the artifact backend re-forwards
            let mut admitted = self.pool.admit(&prompt, native);
            if admitted.is_none()
                && self.pool.compact_mode().enabled()
                && !compacted_on_starve
                && self.pool.in_use() < self.pool.capacity()
            {
                // admit-time page starvation: one compaction pass may
                // free dead pages — retry once per step
                compacted_on_starve = true;
                self.run_compaction();
                admitted = self.pool.admit(&prompt, native);
            }
            let Some(info) = admitted else {
                break;
            };
            let slot = info.slot;
            self.queue.pop_front();
            if let Some(tr) = self.tracer.as_mut() {
                tr.on_admitted(front, Instant::now());
            }
            {
                let s = self.table.get_mut(front);
                s.state = SessionState::Active;
                s.slot = Some(slot);
            }
            // injected allocation failure: the containment contract is
            // the same as real mid-decode page exhaustion — preempt
            // this session (slot + mapped pages released) and keep
            // admitting others
            if self.fire_fault(FaultPoint::PageStarve) {
                self.evict_session(front);
                continue;
            }
            // fault the non-cached prompt pages in (no-op on slab;
            // `admit` just gated on availability, so an error here is
            // an allocator invariant break, not load)
            if let Err(e) = self.pool.ensure_capacity(slot, prompt.len())
            {
                self.fail_session(front);
                return Err(e);
            }
            let logits = if self.fire_fault(FaultPoint::PrefillErr) {
                Err(anyhow!("injected fault: prefill error"))
            } else {
                engine.prefill(rt, self.pool.slot_mut(slot), &prompt)
            };
            let logits = match logits {
                Ok(l) => l,
                Err(_) => {
                    // quarantine: a prefill failure poisons only this
                    // session — release its slot, close its span, and
                    // keep the admit loop (and the core loop) alive
                    self.terminate(front, SpanOutcome::Quarantined);
                    continue;
                }
            };
            // share the freshly computed prompt pages with future
            // sessions (no-op on slab / for partial pages)
            if native {
                self.pool.publish_prefix(slot, &prompt);
            }
            let t_first = Instant::now();
            let s = self.table.get_mut(front);
            let tok = sample_token(&logits, temperature, &mut s.rng);
            s.generated.push(tok);
            s.first_token_at = Some(t_first);
            s.last_token_at = Some(t_first);
            s.last_active_step = self.step_no;
            let ttft_ms =
                t_first.duration_since(s.submitted_at).as_secs_f64() * 1e3;
            self.ttft.record_ms(ttft_ms);
            if let Some(tr) = self.tracer.as_mut() {
                tr.on_first_token(front, t_first);
            }
            // only the computed tail costs prefill work — the cached
            // span was mapped, not recomputed
            self.stats.prefill_tokens +=
                (prompt.len() - info.cached_tokens) as u64;
            self.stats.generated_tokens += 1;
            if s.is_finished() {
                self.finish(front);
            } else {
                self.active.push(front);
            }
        }

        // 2. stall injection (synthetic client disconnects)
        if stall_prob > 0.0 {
            let mut i = 0;
            while i < self.active.len() {
                if workload_rng.uniform() < stall_prob {
                    let id = self.active.swap_remove(i);
                    self.table.get_mut(id).state = SessionState::Stalled;
                    self.stalled.push(id);
                } else {
                    i += 1;
                }
            }
        }

        // 2b. injected per-session faults: clients that vanish
        // mid-generation and single-session engine-step failures.
        // Both are contained here — the faulted session exits with a
        // typed reason and a released slot; the rest of the batch
        // decodes normally this very step.
        if self.faults.is_some() {
            let mut i = 0;
            while i < self.active.len() {
                let id = self.active[i];
                if self.fire_fault(FaultPoint::ClientDrop) {
                    self.active.swap_remove(i);
                    self.terminate(id, SpanOutcome::Disconnected);
                } else if self.fire_fault(FaultPoint::DecodeErr) {
                    self.active.swap_remove(i);
                    self.terminate(id, SpanOutcome::Quarantined);
                } else {
                    i += 1;
                }
            }
        }

        // 3. decode one token for every active session. On the native
        // backend this is a single fused step: the engine stacks every
        // session's hidden state into a [batch, hidden] matrix and
        // runs per-layer GEMMs over the whole batch (step_batch). The
        // artifact backend must re-forward full padded sequences per
        // session, so it keeps the per-session loop.
        let occupancy = self.active.len();
        if occupancy > 0 {
            self.stats.busy_steps += 1;
            self.stats.occupancy_sum += occupancy as u64;
            self.stats.max_occupancy =
                self.stats.max_occupancy.max(occupancy);
        }
        if occupancy > 0 && engine.is_native() {
            // paged layout: fault each session's next write position in
            // before the fused step (no-op on slab, where capacity was
            // reserved whole at admit). A session that cannot grow —
            // the page budget is exhausted and no prefix page is
            // evictable — is preempted (evicted and counted) rather
            // than failing the whole batch.
            let mut i = 0;
            while i < self.active.len() {
                let id = self.active[i];
                let (slot, need) = {
                    let s = self.table.get(id);
                    (s.slot.expect("active session without slot"),
                     s.prompt.len() + s.generated.len())
                };
                let starved = self.fire_fault(FaultPoint::PageStarve);
                if starved
                    || self.pool.ensure_capacity(slot, need).is_err()
                {
                    self.active.swap_remove(i);
                    self.evict_session(id);
                } else {
                    i += 1;
                }
            }
            self.reqs_buf.clear();
            for &id in &self.active {
                let s = self.table.get(id);
                let pos = s.prompt.len() + s.generated.len() - 1;
                // admission samples the first token at prefill, so an
                // active session always has generated history
                let token = *s.generated.last().expect(
                    "active session with no generated tokens",
                );
                self.reqs_buf.push(BatchReq {
                    slot: s.slot.expect("active session without slot"),
                    pos,
                    token,
                });
            }
            let reqs = std::mem::take(&mut self.reqs_buf);
            let step_no = self.step_no;
            let res = {
                let table = &mut self.table;
                let stats = &mut self.stats;
                let active = &self.active;
                engine.step_batch(&mut self.pool, &reqs,
                                  |i, logits| {
                    let s = table.get_mut(active[i]);
                    let tok =
                        sample_token(logits, s.temperature, &mut s.rng);
                    s.generated.push(tok);
                    s.last_active_step = step_no;
                    stats.generated_tokens += 1;
                })
            };
            self.reqs_buf = reqs;
            if let Err(e) = res {
                // step_batch validates every request before touching
                // any KV state, so a failure here is a batch-wide
                // invariant break (desync / bad slot): fail every
                // active session so all slots are reclaimed, then
                // surface the error
                for id in std::mem::take(&mut self.active) {
                    self.fail_session(id);
                }
                return Err(e);
            }
        } else if occupancy > 0 {
            // artifact fallback re-forwards whole padded sequences per
            // session — a per-step Vec is noise next to that, and the
            // clone frees `self.active` for the error path's retain
            let batch: Vec<u64> = self.active.clone();
            for id in batch {
                let s = self.table.get(id);
                let slot = s.slot.expect("active session without slot");
                let temperature = s.temperature;
                let logits = match engine.decode(
                    rt,
                    self.pool.slot_mut(slot),
                    &s.prompt,
                    &s.generated,
                ) {
                    Ok(l) => l,
                    Err(_) => {
                        // per-session forward, per-session blast
                        // radius: quarantine this one and let the
                        // remaining sessions decode their token
                        self.active.retain(|&x| x != id);
                        self.terminate(id, SpanOutcome::Quarantined);
                        continue;
                    }
                };
                let s = self.table.get_mut(id);
                let tok = sample_token(&logits, temperature, &mut s.rng);
                s.generated.push(tok);
                s.last_active_step = self.step_no;
                self.stats.generated_tokens += 1;
            }
        }

        // record inter-token latency: every session still in `active`
        // here decoded exactly one token this step (both backends).
        // One shared timestamp per step keeps the hot-path cost at one
        // clock read + occupancy O(1) histogram records.
        if occupancy > 0 {
            let t_tok = Instant::now();
            for &id in &self.active {
                let s = self.table.get_mut(id);
                if let Some(prev) = s.last_token_at {
                    self.itl.record_ms(
                        t_tok.duration_since(prev).as_secs_f64() * 1e3,
                    );
                }
                s.last_token_at = Some(t_tok);
            }
        }

        // 4. retire finished sessions
        let done: Vec<u64> = self
            .active
            .iter()
            .copied()
            .filter(|&id| self.table.get(id).is_finished())
            .collect();
        for id in done {
            self.active.retain(|&x| x != id);
            self.finish(id);
        }

        // 5. TTL eviction — only sessions in `stalled` can expire, so
        // scan that list, not the whole table
        let mut i = 0;
        while i < self.stalled.len() {
            let id = self.stalled[i];
            let expired = self
                .step_no
                .saturating_sub(self.table.get(id).last_active_step)
                > self.ttl_steps;
            if !expired {
                i += 1;
                continue;
            }
            self.stalled.swap_remove(i);
            self.evict_session(id);
        }

        // 6. brownout pressure tracking (single branch when disabled).
        // Runs on end-of-step state so two identically-seeded runs see
        // identical pressure signals at identical steps.
        if self.brownout.enabled() {
            self.brownout.observe(
                self.queue.len(),
                self.admission.max_queue,
                self.pool.occupancy_frac(),
            );
        }
        Ok(())
    }

    /// One compaction pass over every resident session (active and
    /// stalled), with a per-session `compact_move` fault draw.
    /// A session whose migration drew an injected failure is
    /// quarantined — the pool left its page table untouched
    /// (rollback), so its release through `terminate` reclaims
    /// everything and no other session is disturbed.
    pub fn run_compaction(&mut self) -> CompactReport {
        if !self.pool.compact_mode().enabled() {
            return CompactReport::default();
        }
        let mut ids: Vec<(u64, usize, bool)> = self
            .active
            .iter()
            .chain(self.stalled.iter())
            .filter_map(|&id| {
                self.table.get(id).slot.map(|s| (id, s, false))
            })
            .collect();
        for e in ids.iter_mut() {
            e.2 = self.fire_fault(FaultPoint::CompactMove);
        }
        let slot_ids: Vec<(usize, bool)> =
            ids.iter().map(|&(_, s, f)| (s, f)).collect();
        let report = self.pool.compact(&slot_ids);
        for &(id, slot, _) in &ids {
            if report.failed.contains(&slot) {
                self.active.retain(|&x| x != id);
                self.stalled.retain(|&x| x != id);
                self.terminate(id, SpanOutcome::Quarantined);
            }
        }
        report
    }

    /// Terminal exit for a session whose engine step failed: release
    /// its slot and mark it Evicted so waiting clients unblock and the
    /// pool's capacity survives recoverable errors.
    fn fail_session(&mut self, id: u64) {
        self.evict_session(id);
    }

    /// Plain Evicted exit (TTL expiry, preemption, generic failure).
    fn evict_session(&mut self, id: u64) {
        self.terminate(id, SpanOutcome::Evicted);
    }

    /// Shared abnormal terminal exit: release the slot, stamp the
    /// instant and exit reason, bump the matching counter, close the
    /// span. Every failure path funnels through here, which is what
    /// keeps `DrainReport::clean` an invariant rather than a hope.
    fn terminate(&mut self, id: u64, outcome: SpanOutcome) {
        debug_assert!(outcome.is_failure(), "use finish() for Done");
        let now = Instant::now();
        let s = self.table.get_mut(id);
        s.state = SessionState::Evicted;
        s.finished_at = Some(now);
        s.outcome = Some(outcome);
        let tokens = s.generated.len() as u64;
        if let Some(slot) = s.slot.take() {
            self.pool.release(slot);
        }
        self.stats.evicted += 1;
        match outcome {
            SpanOutcome::DeadlineExceeded => {
                self.stats.deadline_exceeded += 1;
            }
            SpanOutcome::Quarantined => self.stats.quarantined += 1,
            SpanOutcome::Disconnected => self.stats.disconnects += 1,
            _ => {}
        }
        if let Some(tr) = self.tracer.as_mut() {
            tr.on_finish(id, now, tokens, outcome);
        }
    }

    /// Cancel every live session whose deadline has passed, delivering
    /// whatever partial tokens it generated. Gated on `has_deadlines`
    /// by the caller, so deadline-free workloads never pay the scan.
    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .queue
            .iter()
            .chain(self.active.iter())
            .chain(self.stalled.iter())
            .copied()
            .filter(|&id| {
                self.table.get(id).deadline.is_some_and(|d| now >= d)
            })
            .collect();
        for id in expired {
            self.queue.retain(|&x| x != id);
            self.active.retain(|&x| x != id);
            self.stalled.retain(|&x| x != id);
            self.terminate(id, SpanOutcome::DeadlineExceeded);
        }
    }

    fn finish(&mut self, id: u64) {
        let now = Instant::now();
        let s = self.table.get_mut(id);
        s.state = SessionState::Done;
        s.finished_at = Some(now);
        s.outcome = Some(SpanOutcome::Done);
        let tokens = s.generated.len() as u64;
        let e2e_ms =
            now.duration_since(s.submitted_at).as_secs_f64() * 1e3;
        if let Some(slot) = s.slot.take() {
            self.pool.release(slot);
        }
        self.latency.record_ms(e2e_ms);
        self.stats.completed += 1;
        if let Some(tr) = self.tracer.as_mut() {
            tr.on_finish(id, now, tokens, SpanOutcome::Done);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ParamStore};
    use crate::quant::{BitConfig, QuantFormat};

    fn setup(n_slots: usize, max_batch: usize, max_queue: usize)
             -> (Runtime, Engine, Scheduler) {
        let dir = std::env::temp_dir().join("qpruner_serve_sched_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 21);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let max_seq = 24;
        let engine = crate::serve::engine::EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(max_seq)
            .build(&mut rt)
            .unwrap();
        let pool = KvCachePool::with_slots(
            &cfg,
            engine.attn_dim(),
            n_slots,
            max_seq,
            crate::serve::kv_cache::KvPrecision::F32,
            1e6,
            n_slots as f64 * 1e6,
        );
        let sched = Scheduler::new(
            pool,
            AdmissionPolicy::new(max_queue, max_seq),
            max_batch,
            4,
        );
        (rt, engine, sched)
    }

    fn drain(rt: &mut Runtime, engine: &Engine, sched: &mut Scheduler,
             max_steps: u64) {
        let mut rng = Rng::new(99);
        let mut guard = 0;
        while !sched.idle() {
            sched.step(engine, rt, &mut rng, 0.0).unwrap();
            guard += 1;
            assert!(guard < max_steps, "scheduler failed to drain");
        }
    }

    #[test]
    fn single_request_completes() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        let id = sched
            .submit(0, vec![3, 4, 5], 4, 7, 0.8)
            .expect("admitted");
        drain(&mut rt, &engine, &mut sched, 100);
        let s = sched.table.get(id);
        assert_eq!(s.state, SessionState::Done);
        assert_eq!(s.generated.len(), 4);
        assert_eq!(sched.stats.completed, 1);
        assert_eq!(sched.pool.in_use(), 0, "slot leaked");
        assert_eq!(sched.latency.len(), 1);
        assert_eq!(sched.ttft.len(), 1);
    }

    #[test]
    fn batch_grows_and_shrinks_continuously() {
        let (mut rt, engine, mut sched) = setup(4, 4, 16);
        // short and long requests interleaved: the long ones must keep
        // decoding while short ones finish and new ones join
        for i in 0..6 {
            let max_new = if i % 2 == 0 { 2 } else { 10 };
            sched.submit(i, vec![3, 4, 5], max_new, 7, 0.8).unwrap();
        }
        drain(&mut rt, &engine, &mut sched, 500);
        assert_eq!(sched.stats.completed, 6);
        assert!(sched.stats.max_occupancy > 1, "no batching happened");
        assert!(sched.stats.mean_occupancy() > 1.0);
        assert_eq!(sched.pool.in_use(), 0);
        // pool stayed inside its slab
        assert!(sched.pool.peak_in_use() <= sched.pool.capacity());
    }

    #[test]
    fn queue_waits_for_slots() {
        let (mut rt, engine, mut sched) = setup(1, 4, 16);
        for i in 0..3 {
            sched.submit(i, vec![3, 4], 3, 7, 0.0).unwrap();
        }
        // only one slot -> occupancy can never exceed 1
        drain(&mut rt, &engine, &mut sched, 500);
        assert_eq!(sched.stats.completed, 3);
        assert_eq!(sched.stats.max_occupancy, 1);
        assert_eq!(sched.pool.peak_in_use(), 1);
    }

    #[test]
    fn stalled_sessions_are_ttl_evicted_and_slots_reclaimed() {
        let (mut rt, engine, mut sched) = setup(1, 1, 16);
        sched.submit(0, vec![3, 4], 8, 7, 0.0).unwrap();
        sched.submit(1, vec![5, 6], 3, 7, 0.0).unwrap();
        let mut rng = Rng::new(1);
        // force-stall whoever is active after the first step
        sched.step(&engine, &mut rt, &mut rng, 1.0).unwrap();
        assert_eq!(sched.stalled.len(), 1);
        let stalled_id = sched.stalled[0];
        // ttl is 4: run enough steps for eviction + second session
        let mut guard = 0;
        while !sched.idle() {
            sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(sched.table.get(stalled_id).state,
                   SessionState::Evicted);
        assert_eq!(sched.stats.evicted, 1);
        assert_eq!(sched.stats.completed, 1);
        assert_eq!(sched.pool.in_use(), 0, "evicted slot leaked");
    }

    #[test]
    fn tracer_spans_and_itl_match_lifecycle() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        sched.set_tracer(Tracer::new(64));
        sched.submit(0, vec![3, 4, 5], 4, 7, 0.8).unwrap();
        sched.submit(1, vec![5, 6], 3, 7, 0.8).unwrap();
        drain(&mut rt, &engine, &mut sched, 200);
        let tracer = sched.take_tracer().expect("tracer installed");
        assert_eq!(tracer.spans().len(), 2);
        assert_eq!(tracer.live_len(), 0, "span left open");
        assert_eq!(tracer.dropped(), 0);
        for span in tracer.spans() {
            assert_eq!(span.outcome, SpanOutcome::Done);
            assert!(span.admitted.is_some());
            assert!(span.ttft_ms().expect("first token") >= 0.0);
            assert!(span.decode_ms().unwrap() >= 0.0);
            assert!(span.mean_itl_ms().unwrap() >= 0.0);
        }
        let max_new: u64 = tracer.spans().iter().map(|s| s.tokens).sum();
        assert_eq!(max_new, sched.stats.generated_tokens);
        // each session records one ITL sample per token after its
        // first: total = generated - completed
        assert_eq!(
            sched.itl.len() as u64,
            sched.stats.generated_tokens - sched.stats.completed as u64,
        );
        // percentiles from the log2 histogram must be ordered
        let p = sched.itl.percentiles_ms(&[50.0, 95.0, 99.0]);
        assert!(p[0] <= p[1] && p[1] <= p[2]);
    }

    #[test]
    fn cancel_frees_slots_from_any_list_and_is_idempotent() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        let mut rng = Rng::new(5);
        // queued cancel: three submits, two slots
        let a = sched.submit(0, vec![3, 4], 8, 7, 0.0).unwrap();
        let b = sched.submit(1, vec![3, 4], 8, 7, 0.0).unwrap();
        let c = sched.submit(2, vec![3, 4], 8, 7, 0.0).unwrap();
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        assert_eq!(sched.queue_len(), 1, "c still waits");
        assert!(sched.cancel(c), "queued session cancels");
        assert_eq!(sched.queue_len(), 0);
        // active cancel releases the slot for reuse
        assert!(sched.cancel(a));
        assert_eq!(sched.table.get(a).state, SessionState::Evicted);
        assert_eq!(sched.pool.in_use(), 1, "a's slot reclaimed");
        // double-cancel and cancel-after-finish are no-ops
        assert!(!sched.cancel(a));
        drain(&mut rt, &engine, &mut sched, 100);
        assert_eq!(sched.table.get(b).state, SessionState::Done);
        assert!(!sched.cancel(b));
        assert!(!sched.cancel(999_999), "unknown id is a no-op");
        assert_eq!(sched.stats.evicted, 2);
        assert_eq!(sched.stats.completed, 1);
        assert_eq!(sched.pool.in_use(), 0);
    }

    #[test]
    fn deadline_cancels_with_partial_tokens() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        sched.set_tracer(Tracer::new(16));
        let mut rng = Rng::new(1);
        // a: already expired at submit; b: effectively unbounded
        let a = sched
            .submit_req(0, vec![3, 4], 8, 7, 0.0, Some(0))
            .unwrap();
        let b = sched
            .submit_req(1, vec![3, 4], 3, 7, 0.0, Some(600_000))
            .unwrap();
        let mut guard = 0;
        while !sched.idle() {
            sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(sched.table.get(a).state, SessionState::Evicted);
        assert_eq!(sched.table.get(a).outcome,
                   Some(SpanOutcome::DeadlineExceeded));
        assert_eq!(sched.table.get(b).state, SessionState::Done);
        assert_eq!(sched.stats.deadline_exceeded, 1);
        assert_eq!(sched.stats.evicted, 1);
        assert_eq!(sched.stats.completed, 1);
        assert_eq!(sched.pool.in_use(), 0, "deadline leak");
        let tr = sched.take_tracer().unwrap();
        assert_eq!(tr.live_len(), 0);
        let span_a = tr.spans().iter().find(|s| s.id == a).unwrap();
        assert_eq!(span_a.outcome, SpanOutcome::DeadlineExceeded);
    }

    #[test]
    fn default_deadline_applies_to_plain_submits() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        sched.set_default_deadline_ms(Some(0));
        let id = sched.submit(0, vec![3, 4], 8, 7, 0.0).unwrap();
        let mut rng = Rng::new(1);
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        assert_eq!(sched.table.get(id).outcome,
                   Some(SpanOutcome::DeadlineExceeded));
        assert!(sched.idle());
    }

    #[test]
    fn prefill_fault_quarantines_session_not_loop() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        sched.set_tracer(Tracer::new(16));
        sched.set_faults(
            crate::serve::faults::FaultPlan::parse("seed=1,prefill_err")
                .unwrap(),
        );
        for i in 0..3 {
            sched.submit(i, vec![3, 4], 4, 7, 0.0).unwrap();
        }
        let mut rng = Rng::new(1);
        let mut guard = 0;
        while !sched.idle() {
            // the loop must survive every injected prefill failure
            sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(sched.stats.quarantined, 3);
        assert_eq!(sched.stats.completed, 0);
        assert_eq!(sched.pool.in_use(), 0, "quarantine leak");
        assert_eq!(sched.faults().unwrap().total_fired(), 3);
        let tr = sched.take_tracer().unwrap();
        assert_eq!(tr.live_len(), 0);
        assert!(tr
            .spans()
            .iter()
            .all(|s| s.outcome == SpanOutcome::Quarantined));
    }

    #[test]
    fn injected_drops_and_decode_errs_are_contained() {
        let (mut rt, engine, mut sched) = setup(4, 4, 32);
        sched.set_faults(
            crate::serve::faults::FaultPlan::parse(
                "seed=9,client_drop=0.2,decode_err=0.2",
            )
            .unwrap(),
        );
        for i in 0..12 {
            sched.submit(i, vec![3, 4, 5], 10, 7, 0.8).unwrap();
        }
        drain(&mut rt, &engine, &mut sched, 2000);
        let st = &sched.stats;
        assert!(st.disconnects + st.quarantined > 0,
                "0.2+0.2 over 12 long sessions should fire");
        assert_eq!(st.completed + st.evicted, 12);
        assert_eq!(sched.pool.in_use(), 0);
    }

    #[test]
    fn injected_page_starve_preempts_cleanly() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        sched.set_faults(
            crate::serve::faults::FaultPlan::parse("seed=4,page_starve")
                .unwrap(),
        );
        for i in 0..3 {
            sched.submit(i, vec![3, 4], 4, 7, 0.0).unwrap();
        }
        drain(&mut rt, &engine, &mut sched, 200);
        assert_eq!(sched.stats.evicted, 3);
        assert_eq!(sched.stats.completed, 0);
        assert_eq!(sched.pool.in_use(), 0, "starved admit leaked");
    }

    #[test]
    fn cancel_as_records_disconnect_reason() {
        let (mut rt, engine, mut sched) = setup(2, 2, 8);
        let id = sched.submit(0, vec![3, 4], 8, 7, 0.0).unwrap();
        let mut rng = Rng::new(1);
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        assert!(sched.cancel_as(id, SpanOutcome::Disconnected));
        assert_eq!(sched.table.get(id).outcome,
                   Some(SpanOutcome::Disconnected));
        assert_eq!(sched.stats.disconnects, 1);
        assert_eq!(sched.stats.evicted, 1);
        assert_eq!(sched.pool.in_use(), 0);
    }

    #[test]
    fn brownout_clamps_admission_and_bumps_retry_after() {
        let (mut rt, engine, mut sched) = setup(1, 1, 4);
        sched.set_brownout(Some(BrownoutConfig {
            queue_frac: 0.5,
            enter_steps: 1,
            clamp_max_new: 2,
            retry_after_bump: 3,
            ..Default::default()
        }));
        let base = sched.retry_after_secs(0);
        // queue 3 of 4 (> 0.5 frac) behind a single busy slot
        for i in 0..4 {
            sched.submit(i, vec![3, 4], 20, 7, 0.0).unwrap();
        }
        let mut rng = Rng::new(1);
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        assert!(sched.brownout.active(), "sustained queue pressure");
        assert_eq!(sched.retry_after_secs(0), base + 3);
        // submissions during brownout get the degraded budget
        let id = sched.submit(9, vec![3, 4], 20, 7, 0.0).unwrap();
        assert_eq!(sched.table.get(id).max_new, 2);
        drain(&mut rt, &engine, &mut sched, 500);
        assert_eq!(sched.pool.in_use(), 0);
    }

    #[test]
    fn rejection_counted_when_queue_full() {
        let (_rt, _engine, mut sched) = setup(1, 1, 2);
        assert!(sched.submit(0, vec![3], 2, 7, 0.0).is_some());
        assert!(sched.submit(1, vec![3], 2, 7, 0.0).is_some());
        assert!(sched.submit(2, vec![3], 2, 7, 0.0).is_none());
        assert_eq!(sched.stats.rejected, 1);
        assert_eq!(sched.stats.rejected_queue_full, 1);
        assert_eq!(sched.stats.submitted, 3);
        assert!((sched.stats.rejection_rate() - 1.0 / 3.0).abs() < 1e-9);
        // an oversized request lands in the too-long bucket
        assert!(sched.submit(3, vec![3; 30], 30, 7, 0.0).is_none());
        assert_eq!(sched.stats.rejected_too_long, 1);
        assert_eq!(sched.stats.rejected, 2);
    }
}
