//! Slab-allocated KV-cache pool for the serving subsystem.
//!
//! All session KV storage is preallocated up front as fixed-size slots
//! (one per concurrently-resident session), so the decode path never
//! allocates or frees *KV storage* and cannot exceed its memory budget
//! by construction (the engine's per-token activation scratch is a
//! separate concern — see the ROADMAP item on fused batched decode).
//! Capacity derives from the precision-aware accounting in
//! `memory.rs`: the number of slots is what the modeled deployment
//! device could pin inside `serve_kv_budget_gb` (device headroom left
//! after the active `BitConfig`'s inference footprint), capped by
//! what the scheduler can actually keep resident (its batch cap plus
//! a stall allowance) and a hard host-side slab limit.

use crate::memory;
use crate::model::ModelConfig;
use anyhow::{bail, Result};

/// Per-session KV storage: K and V stacks laid out `[L, max_seq, A]`
/// contiguously (f32 host precision; the *modeled* deployment precision
/// is fp16 — see `memory::kv_bytes_per_session`).
#[derive(Debug)]
pub struct KvSlot {
    k: Vec<f32>,
    v: Vec<f32>,
    /// tokens currently cached (positions `0..len` are valid)
    pub len: usize,
    n_layers: usize,
    max_seq: usize,
    attn_dim: usize,
}

impl KvSlot {
    fn new(n_layers: usize, max_seq: usize, attn_dim: usize) -> KvSlot {
        KvSlot {
            k: vec![0.0; n_layers * max_seq * attn_dim],
            v: vec![0.0; n_layers * max_seq * attn_dim],
            len: 0,
            n_layers,
            max_seq,
            attn_dim,
        }
    }

    #[inline]
    fn off(&self, layer: usize, t: usize) -> usize {
        debug_assert!(layer < self.n_layers && t < self.max_seq);
        (layer * self.max_seq + t) * self.attn_dim
    }

    /// Write the K/V rows for position `t` of `layer`. The caller
    /// advances `len` once per token via [`KvSlot::advance_to`].
    pub fn write(&mut self, layer: usize, t: usize, k_row: &[f32],
                 v_row: &[f32]) {
        assert!(t < self.max_seq, "kv overflow: pos {t} >= {}", self.max_seq);
        assert_eq!(k_row.len(), self.attn_dim);
        let o = self.off(layer, t);
        self.k[o..o + self.attn_dim].copy_from_slice(k_row);
        self.v[o..o + self.attn_dim].copy_from_slice(v_row);
    }

    pub fn advance_to(&mut self, len: usize) {
        debug_assert!(len <= self.max_seq);
        self.len = len;
    }

    #[inline]
    pub fn k_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        &self.k[o..o + self.attn_dim]
    }

    #[inline]
    pub fn v_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        &self.v[o..o + self.attn_dim]
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn reset(&mut self) {
        self.len = 0; // stale K/V rows are overwritten before reads
    }

    /// Host bytes of this slot's backing storage.
    pub fn host_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// Fixed-capacity pool of [`KvSlot`]s with a free list.
pub struct KvCachePool {
    slots: Vec<KvSlot>,
    free: Vec<usize>,
    /// modeled deployment bytes one session pins (fp16, paper arch)
    modeled_bytes_per_session: f64,
    /// modeled deployment budget in bytes
    modeled_budget_bytes: f64,
    peak_in_use: usize,
}

/// Hard host-side cap on preallocated slots, independent of how large
/// the modeled device headroom is (keeps the simulator's RSS bounded).
pub const MAX_HOST_SLOTS: usize = 1024;

impl KvCachePool {
    /// Size the pool from the modeled deployment: `budget_gb` of KV
    /// headroom on the target device (see `memory::serve_kv_budget_gb`)
    /// divided by the per-session KV bytes of the paper-scale
    /// architecture at this pruning rate. Host slots are shaped by the
    /// *served* (simulator) model config and capped at
    /// `host_slot_cap` — the scheduler's reachable concurrency — so a
    /// huge modeled headroom doesn't preallocate megabytes of slab no
    /// session can ever touch.
    pub fn for_budget(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        paper_cfg: &ModelConfig,
        rate_pct: u32,
        max_seq: usize,
        budget_gb: f64,
        host_slot_cap: usize,
    ) -> Result<KvCachePool> {
        let per_session =
            memory::kv_bytes_per_session(paper_cfg, rate_pct, max_seq);
        let budget_bytes = budget_gb * 1e9;
        let n = (budget_bytes / per_session).floor() as usize;
        if n == 0 {
            bail!(
                "KV budget {budget_gb:.3} GB holds zero sessions \
                 ({:.1} MB each at max_seq {max_seq}) — raise \
                 --kv-budget-gb or lower --max-seq",
                per_session / 1e6
            );
        }
        Ok(Self::with_slots(
            host_cfg,
            host_attn_dim,
            n.min(MAX_HOST_SLOTS).min(host_slot_cap.max(1)),
            max_seq,
            per_session,
            budget_bytes,
        ))
    }

    /// Direct construction with an explicit slot count (tests).
    pub fn with_slots(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        n_slots: usize,
        max_seq: usize,
        modeled_bytes_per_session: f64,
        modeled_budget_bytes: f64,
    ) -> KvCachePool {
        assert!(n_slots > 0);
        let slots = (0..n_slots)
            .map(|_| KvSlot::new(host_cfg.n_layers, max_seq, host_attn_dim))
            .collect();
        KvCachePool {
            slots,
            free: (0..n_slots).rev().collect(),
            modeled_bytes_per_session,
            modeled_budget_bytes,
            peak_in_use: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Modeled deployment bytes currently pinned / at peak.
    pub fn modeled_peak_bytes(&self) -> f64 {
        self.peak_in_use as f64 * self.modeled_bytes_per_session
    }

    pub fn modeled_budget_bytes(&self) -> f64 {
        self.modeled_budget_bytes
    }

    /// Host bytes of the whole preallocated slab.
    pub fn host_slab_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.host_bytes()).sum()
    }

    /// Claim a free slot; `None` when the budget is exhausted (callers
    /// queue or reject — see `admission.rs`).
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        self.slots[id].reset();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(id)
    }

    /// Return a slot to the free list.
    pub fn release(&mut self, id: usize) {
        debug_assert!(!self.free.contains(&id), "double release of {id}");
        self.slots[id].reset();
        self.free.push(id);
    }

    pub fn slot(&self, id: usize) -> &KvSlot {
        &self.slots[id]
    }

    pub fn slot_mut(&mut self, id: usize) -> &mut KvSlot {
        &mut self.slots[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BitConfig, QuantFormat};

    fn pool(n: usize) -> KvCachePool {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = cfg.pruned(0).attn_dim(&cfg);
        KvCachePool::with_slots(&cfg, a, n, 16, 1e6, n as f64 * 1e6)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        assert!(p.alloc().is_none(), "over-allocation must fail");
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "free list reuses the released slot");
        assert_eq!(p.peak_in_use(), 2);
    }

    #[test]
    fn released_slot_is_reset() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let (k, v) = (vec![1.0; a], vec![2.0; a]);
        p.slot_mut(id).write(0, 0, &k, &v);
        p.slot_mut(id).advance_to(1);
        assert_eq!(p.slot(id).len, 1);
        p.release(id);
        let id2 = p.alloc().unwrap();
        assert_eq!(p.slot(id2).len, 0);
    }

    #[test]
    fn slot_rows_roundtrip() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let k: Vec<f32> = (0..a).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..a).map(|i| -(i as f32)).collect();
        p.slot_mut(id).write(1, 3, &k, &v);
        assert_eq!(p.slot(id).k_at(1, 3), &k[..]);
        assert_eq!(p.slot(id).v_at(1, 3), &v[..]);
        // other positions untouched
        assert!(p.slot(id).k_at(1, 2).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn budget_sizing_matches_memory_accounting() {
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per = memory::kv_bytes_per_session(&paper, 20, 64);
        // budget for exactly 3 sessions
        let gb = 3.0 * per / 1e9 + 1e-12;
        let p =
            KvCachePool::for_budget(&host, a, &paper, 20, 64, gb, 64)
                .unwrap();
        assert_eq!(p.capacity(), 3);
        // capacity * per-session never exceeds the budget
        assert!(p.capacity() as f64 * per <= p.modeled_budget_bytes());
        // the scheduler-reachable cap wins when it is tighter
        let capped =
            KvCachePool::for_budget(&host, a, &paper, 20, 64, gb, 2)
                .unwrap();
        assert_eq!(capped.capacity(), 2);
        // zero-session budgets are a hard error
        assert!(KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                        per / 1e9 * 0.5, 64)
            .is_err());
    }

    #[test]
    fn budget_grows_with_quantization_headroom() {
        // nf4 leaves more device headroom than fp16 -> more sessions
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let dev = 8.0;
        let b4 = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Nf4), dev);
        let bf = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Fp16), dev);
        assert!(b4 > 0.0);
        let p4 =
            KvCachePool::for_budget(&host, a, &paper, 20, 256, b4,
                                    MAX_HOST_SLOTS)
                .unwrap();
        if bf > 0.0 {
            let pf =
                KvCachePool::for_budget(&host, a, &paper, 20, 256, bf,
                                        MAX_HOST_SLOTS)
                    .unwrap();
            assert!(p4.capacity() >= pf.capacity());
        } else {
            assert!(p4.capacity() >= 1);
        }
    }
}
