//! KV-cache pool for the serving subsystem: slab or paged layout, with
//! a selectable per-element precision.
//!
//! Two layouts ([`KvLayout`], `--kv-layout` on the CLI):
//!
//! * **Slab** — one contiguous `[L, max_seq, A]` reservation per
//!   concurrently-resident session, preallocated up front. The decode
//!   path never allocates or frees KV storage and cannot exceed its
//!   memory budget by construction. This is the original layout and
//!   survives as the parity oracle and bench baseline.
//! * **Paged** — fixed-size pages of `page_tokens` positions
//!   (`[L, page_tokens, A]` for both K and V) handed out from a free
//!   list, with a per-session page table mapping logical token
//!   positions to pages. Pages are ref-counted (`Arc`), so sessions
//!   sharing a prompt prefix share read-only pages: a **prefix index**
//!   keyed by a rolling FNV-1a hash of the token prefix (verified
//!   against the stored tokens, so hash collisions cannot alias) lets
//!   [`KvCachePool::admit`] map already-computed pages into a new
//!   session's table and skip prefill for the shared span.
//!   Copy-on-write protects divergence: [`KvCachePool::ensure_capacity`]
//!   faults unmapped pages in and privatizes (copies) any shared page
//!   in the write range before [`KvSlot::write`] touches it, so a
//!   session can never mutate a page another session (or the prefix
//!   index) still references. Page storage is preallocated like the
//!   slab layout — faults and CoW copies pop from the free list (and
//!   under pressure evict least-recently-used single-referenced prefix
//!   entries), never the allocator.
//!
//! Capacity derives from the precision-aware accounting in
//! `memory.rs`: slab capacity is whole-session reservations inside
//! `serve_kv_budget_gb`; paged capacity is the **page budget**
//! (`memory::kv_page_bytes`), so short sessions no longer strand a
//! worst-case `max_seq` slab and the same budget admits strictly more
//! of them (see `paged_budget_admits_2x_short_sessions`).
//!
//! Two KV representations ([`KvPrecision`], `--kv-bits` on the CLI):
//!
//! * **F32** — plain f32 rows (4 bytes/element), the exact numerics of
//!   the incremental decode reference path;
//! * **Int8** — signed int8 codes with per-[`quant::BLOCK`] f32 absmax
//!   scales, reusing the blockwise quantizer from `quant.rs` (the same
//!   scheme the paper applies to weights, extended to the KV cache the
//!   way QLoRA-style double quantization trades precision for serving
//!   memory). ~3.8x smaller than f32, so `for_budget` admits
//!   proportionally more concurrent sessions.
//!
//! Rows are written and read through the same `KvStore` helpers in
//! both layouts (a page is just a short-`rows` store), so a paged
//! session reproduces the slab session's values **bit-identically** —
//! `tests/parity_decode.rs` pins paged-vs-slab logits with `==`, and
//! `tests/fuzz_paged_kv.rs` hammers the allocator invariants
//! (no double-assignment, refcounts match the tables,
//! `free + used == total`, full reclamation at drain).

use crate::memory;
use crate::model::ModelConfig;
use crate::quant::{self, BLOCK};
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Storage precision of the KV cache (`--kv-bits {32,8}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPrecision {
    /// f32 rows, bit-exact with the reference decode path.
    F32,
    /// int8 codes + per-block absmax scales (`quant::quantize_row_i8`).
    Int8,
}

impl KvPrecision {
    /// Map the CLI `--kv-bits` value onto a precision.
    pub fn from_bits(bits: u32) -> Option<KvPrecision> {
        match bits {
            32 => Some(KvPrecision::F32),
            8 => Some(KvPrecision::Int8),
            _ => None,
        }
    }

    pub fn bits(self) -> u32 {
        match self {
            KvPrecision::F32 => 32,
            KvPrecision::Int8 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::Int8 => "int8",
        }
    }

    /// Modeled deployment bytes per KV element, including the
    /// per-block f32 scale amortized over the block for Int8 (mirrors
    /// `QuantFormat::bits_per_param`). Feeds
    /// `memory::kv_bytes_per_session_at`.
    pub fn modeled_bytes_per_elem(self) -> f64 {
        match self {
            KvPrecision::F32 => 4.0,
            KvPrecision::Int8 => 1.0 + 4.0 / BLOCK as f64,
        }
    }
}

/// KV storage layout (`--kv-layout {slab,paged}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// One contiguous max_seq reservation per session (the original
    /// layout; parity oracle and bench baseline).
    Slab,
    /// Fixed-size token pages from a free list, per-session page
    /// tables, ref-counted prefix sharing with copy-on-write.
    Paged,
}

impl KvLayout {
    pub fn parse(s: &str) -> Option<KvLayout> {
        match s {
            "slab" => Some(KvLayout::Slab),
            "paged" => Some(KvLayout::Paged),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KvLayout::Slab => "slab",
            KvLayout::Paged => "paged",
        }
    }
}

/// When the paged pool compacts (`--compact {off,starve,thresh=P}`).
/// Any enabled mode also turns on sub-page prefix matching — the two
/// ship together because sub-page publishing is what makes short
/// shared prompts (< `page_tokens`) reusable, and compaction is what
/// keeps the extra index-owned pages from stranding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompactMode {
    /// never compact (the pre-compaction behavior, bit-for-bit)
    Off,
    /// compact only when an admission starves for pages
    Starve,
    /// compact whenever the fragmentation fraction
    /// ([`KvCachePool::frag_frac`]) reaches the threshold
    Thresh(f64),
}

impl CompactMode {
    /// Parse the CLI `--compact` value: `off`, `starve`, `thresh=P`.
    pub fn parse(s: &str) -> Option<CompactMode> {
        match s {
            "off" => Some(CompactMode::Off),
            "starve" => Some(CompactMode::Starve),
            _ => {
                let p = s.strip_prefix("thresh=")?;
                let p: f64 = p.parse().ok()?;
                if p.is_finite() && (0.0..=1.0).contains(&p) {
                    Some(CompactMode::Thresh(p))
                } else {
                    None
                }
            }
        }
    }

    pub fn enabled(self) -> bool {
        !matches!(self, CompactMode::Off)
    }

    pub fn label(self) -> String {
        match self {
            CompactMode::Off => "off".into(),
            CompactMode::Starve => "starve".into(),
            CompactMode::Thresh(p) => format!("thresh={p}"),
        }
    }
}

/// What one [`KvCachePool::compact`] pass did.
#[derive(Clone, Debug, Default)]
pub struct CompactReport {
    /// pages returned to the free list by this pass
    pub pages_reclaimed: usize,
    /// partial shared tail pages whose live rows were migrated into a
    /// fresh private page (the shared original is never written)
    pub migrated: usize,
    /// slot ids whose migration drew an injected `compact_move` fault:
    /// the copy aborted before any table change, so their live pages
    /// and token payloads are intact (callers quarantine them)
    pub failed: Vec<usize>,
}

/// Backing storage for `rows` token positions across `n_layers`
/// layers, laid out `[L, rows, A]` contiguously for both K and V.
/// A slab slot is one store with `rows == max_seq`; a page is one
/// store with `rows == page_tokens`. Both layouts go through the same
/// row read/write helpers, which is what makes paged decode
/// bit-identical to slab decode.
#[derive(Debug)]
struct KvStore {
    data: KvData,
    n_layers: usize,
    rows: usize,
    attn_dim: usize,
    /// quantization blocks per KV row (Int8 only, also 1-based for F32
    /// so offsets stay uniform)
    blocks_per_row: usize,
}

#[derive(Debug)]
enum KvData {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Int8 {
        k_codes: Vec<i8>,
        v_codes: Vec<i8>,
        /// per-(layer, position, block) absmax scales,
        /// `[L, rows, blocks_per_row]`
        k_scales: Vec<f32>,
        v_scales: Vec<f32>,
    },
}

impl KvStore {
    fn new(n_layers: usize, rows: usize, attn_dim: usize,
           precision: KvPrecision) -> KvStore {
        let n = n_layers * rows * attn_dim;
        let blocks_per_row = attn_dim.div_ceil(BLOCK);
        let data = match precision {
            KvPrecision::F32 => KvData::F32 {
                k: vec![0.0; n],
                v: vec![0.0; n],
            },
            KvPrecision::Int8 => {
                let ns = n_layers * rows * blocks_per_row;
                KvData::Int8 {
                    k_codes: vec![0; n],
                    v_codes: vec![0; n],
                    k_scales: vec![0.0; ns],
                    v_scales: vec![0.0; ns],
                }
            }
        };
        KvStore { data, n_layers, rows, attn_dim, blocks_per_row }
    }

    fn precision(&self) -> KvPrecision {
        match self.data {
            KvData::F32 { .. } => KvPrecision::F32,
            KvData::Int8 { .. } => KvPrecision::Int8,
        }
    }

    #[inline]
    fn off(&self, layer: usize, t: usize) -> usize {
        debug_assert!(layer < self.n_layers && t < self.rows);
        (layer * self.rows + t) * self.attn_dim
    }

    #[inline]
    fn scale_off(&self, layer: usize, t: usize) -> usize {
        (layer * self.rows + t) * self.blocks_per_row
    }

    fn write_row(&mut self, layer: usize, t: usize, k_row: &[f32],
                 v_row: &[f32]) {
        assert!(t < self.rows, "kv overflow: row {t} >= {}", self.rows);
        assert_eq!(k_row.len(), self.attn_dim);
        assert_eq!(v_row.len(), self.attn_dim);
        let o = self.off(layer, t);
        let so = self.scale_off(layer, t);
        let a = self.attn_dim;
        let nb = self.blocks_per_row;
        match &mut self.data {
            KvData::F32 { k, v } => {
                k[o..o + a].copy_from_slice(k_row);
                v[o..o + a].copy_from_slice(v_row);
            }
            KvData::Int8 { k_codes, v_codes, k_scales, v_scales } => {
                quant::quantize_row_i8(k_row, &mut k_codes[o..o + a],
                                       &mut k_scales[so..so + nb]);
                quant::quantize_row_i8(v_row, &mut v_codes[o..o + a],
                                       &mut v_scales[so..so + nb]);
            }
        }
    }

    fn k_row<'a>(&'a self, layer: usize, t: usize,
                 scratch: &'a mut [f32]) -> &'a [f32] {
        let o = self.off(layer, t);
        let a = self.attn_dim;
        match &self.data {
            KvData::F32 { k, .. } => &k[o..o + a],
            KvData::Int8 { k_codes, k_scales, .. } => {
                let so = self.scale_off(layer, t);
                quant::dequantize_row_i8(
                    &k_codes[o..o + a],
                    &k_scales[so..so + self.blocks_per_row],
                    &mut scratch[..a],
                );
                &scratch[..a]
            }
        }
    }

    fn v_row<'a>(&'a self, layer: usize, t: usize,
                 scratch: &'a mut [f32]) -> &'a [f32] {
        let o = self.off(layer, t);
        let a = self.attn_dim;
        match &self.data {
            KvData::F32 { v, .. } => &v[o..o + a],
            KvData::Int8 { v_codes, v_scales, .. } => {
                let so = self.scale_off(layer, t);
                quant::dequantize_row_i8(
                    &v_codes[o..o + a],
                    &v_scales[so..so + self.blocks_per_row],
                    &mut scratch[..a],
                );
                &scratch[..a]
            }
        }
    }

    fn k_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        match &self.data {
            KvData::F32 { k, .. } => &k[o..o + self.attn_dim],
            KvData::Int8 { .. } => {
                panic!("k_at on an int8 store; use k_row with scratch")
            }
        }
    }

    fn v_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        match &self.data {
            KvData::F32 { v, .. } => &v[o..o + self.attn_dim],
            KvData::Int8 { .. } => {
                panic!("v_at on an int8 store; use v_row with scratch")
            }
        }
    }

    /// Byte-for-byte copy of another store with identical shape (the
    /// CoW privatization step — no requantization, so a privatized
    /// page reads back bit-identically to the shared original).
    fn copy_from(&mut self, src: &KvStore) {
        match (&mut self.data, &src.data) {
            (KvData::F32 { k, v }, KvData::F32 { k: sk, v: sv }) => {
                k.copy_from_slice(sk);
                v.copy_from_slice(sv);
            }
            (
                KvData::Int8 { k_codes, v_codes, k_scales, v_scales },
                KvData::Int8 {
                    k_codes: skc,
                    v_codes: svc,
                    k_scales: sks,
                    v_scales: svs,
                },
            ) => {
                k_codes.copy_from_slice(skc);
                v_codes.copy_from_slice(svc);
                k_scales.copy_from_slice(sks);
                v_scales.copy_from_slice(svs);
            }
            _ => panic!("KvStore::copy_from across precisions"),
        }
    }

    /// Byte-for-byte copy of rows `0..n` from `src` across every layer
    /// (same `attn_dim` / `n_layers`; row counts may differ). Used by
    /// sub-page prefix mapping and compaction migration: codes and
    /// scales move verbatim — no requantization — so the copied rows
    /// read back bit-identically to the source page.
    fn copy_rows_from(&mut self, src: &KvStore, n: usize) {
        assert!(n <= self.rows && n <= src.rows,
                "row-range copy {n} exceeds page rows");
        assert_eq!(self.attn_dim, src.attn_dim);
        assert_eq!(self.n_layers, src.n_layers);
        let a = self.attn_dim;
        let nb = self.blocks_per_row;
        for layer in 0..self.n_layers {
            let d = (layer * self.rows) * a;
            let s = (layer * src.rows) * a;
            let ds = (layer * self.rows) * nb;
            let ss = (layer * src.rows) * nb;
            match (&mut self.data, &src.data) {
                (KvData::F32 { k, v }, KvData::F32 { k: sk, v: sv }) => {
                    k[d..d + n * a].copy_from_slice(&sk[s..s + n * a]);
                    v[d..d + n * a].copy_from_slice(&sv[s..s + n * a]);
                }
                (
                    KvData::Int8 { k_codes, v_codes, k_scales, v_scales },
                    KvData::Int8 {
                        k_codes: skc,
                        v_codes: svc,
                        k_scales: sks,
                        v_scales: svs,
                    },
                ) => {
                    k_codes[d..d + n * a]
                        .copy_from_slice(&skc[s..s + n * a]);
                    v_codes[d..d + n * a]
                        .copy_from_slice(&svc[s..s + n * a]);
                    k_scales[ds..ds + n * nb]
                        .copy_from_slice(&sks[ss..ss + n * nb]);
                    v_scales[ds..ds + n * nb]
                        .copy_from_slice(&svs[ss..ss + n * nb]);
                }
                _ => panic!("KvStore::copy_rows_from across precisions"),
            }
        }
    }

    /// Host bytes of this store's backing buffers.
    fn host_bytes(&self) -> usize {
        match &self.data {
            KvData::F32 { k, v } => {
                (k.len() + v.len()) * std::mem::size_of::<f32>()
            }
            KvData::Int8 { k_codes, v_codes, k_scales, v_scales } => {
                k_codes.len() + v_codes.len()
                    + (k_scales.len() + v_scales.len())
                        * std::mem::size_of::<f32>()
            }
        }
    }
}

/// One fixed-size KV page: `page_tokens` positions for every layer,
/// K and V. Ref-counted via `Arc` — the strong count *is* the page's
/// refcount (page tables and prefix-index entries each hold one
/// clone), and `Arc::get_mut` is the write-privacy proof the paged
/// [`KvSlot::write`] path relies on.
#[derive(Debug)]
pub struct KvPage {
    id: u32,
    store: KvStore,
}

impl KvPage {
    pub fn id(&self) -> u32 {
        self.id
    }
}

/// Backing storage of one session slot.
#[derive(Debug)]
enum KvBacking {
    /// one contiguous `[L, max_seq, A]` store
    Slab(KvStore),
    /// page table: logical page `p` covers token positions
    /// `p*page_tokens .. (p+1)*page_tokens`
    Paged {
        pages: Vec<Arc<KvPage>>,
        page_tokens: usize,
    },
}

/// Per-session KV storage: K and V stacks for every layer, position
/// and attention channel, at the pool's [`KvPrecision`], backed by
/// either a slab or a page table per the pool's [`KvLayout`].
#[derive(Debug)]
pub struct KvSlot {
    backing: KvBacking,
    /// tokens currently cached (positions `0..len` are valid)
    pub len: usize,
    max_seq: usize,
    attn_dim: usize,
    precision: KvPrecision,
}

impl KvSlot {
    fn new_slab(n_layers: usize, max_seq: usize, attn_dim: usize,
                precision: KvPrecision) -> KvSlot {
        KvSlot {
            backing: KvBacking::Slab(KvStore::new(
                n_layers, max_seq, attn_dim, precision,
            )),
            len: 0,
            max_seq,
            attn_dim,
            precision,
        }
    }

    fn new_paged(max_seq: usize, attn_dim: usize,
                 precision: KvPrecision, page_tokens: usize) -> KvSlot {
        KvSlot {
            backing: KvBacking::Paged { pages: Vec::new(), page_tokens },
            len: 0,
            max_seq,
            attn_dim,
            precision,
        }
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    /// Write the K/V rows for position `t` of `layer` (quantizing when
    /// the slot is Int8). The caller advances `len` once per token via
    /// [`KvSlot::advance_to`]. On a paged slot the target page must be
    /// mapped *and private* — [`KvCachePool::ensure_capacity`]
    /// establishes both (faulting and copy-on-write), so a write can
    /// never reach a page another session or the prefix index still
    /// references.
    pub fn write(&mut self, layer: usize, t: usize, k_row: &[f32],
                 v_row: &[f32]) {
        assert!(t < self.max_seq, "kv overflow: pos {t} >= {}", self.max_seq);
        match &mut self.backing {
            KvBacking::Slab(store) => store.write_row(layer, t, k_row, v_row),
            KvBacking::Paged { pages, page_tokens } => {
                let (p, within) = (t / *page_tokens, t % *page_tokens);
                assert!(p < pages.len(),
                        "write to unmapped page {p} (pos {t}); call \
                         KvCachePool::ensure_capacity first");
                let page = Arc::get_mut(&mut pages[p]).expect(
                    "write to a shared page — ensure_capacity must \
                     copy-on-write before any write",
                );
                page.store.write_row(layer, within, k_row, v_row);
            }
        }
    }

    pub fn advance_to(&mut self, len: usize) {
        debug_assert!(len <= self.max_seq);
        self.len = len;
    }

    /// Roll the cached length back (speculative rollback / fuzz
    /// rewind). Pages beyond the new tail stay mapped — the cheap fast
    /// path when the session re-extends — and become the dead-page
    /// fragmentation that [`KvCachePool::compact`] reclaims.
    pub fn rewind(&mut self, len: usize) {
        assert!(len <= self.len, "rewind {len} past live len {}", self.len);
        self.len = len;
    }

    /// K row at (layer, t) as f32: a direct slice for F32 storage, a
    /// dequantization into `scratch` for Int8 (scratch must hold at
    /// least `attn_dim` values). The returned slice borrows whichever
    /// storage backs it, so the engine's hot loop never copies on the
    /// f32 path and never allocates on either; paged slots add one
    /// divide/modulo for the page-table walk.
    pub fn k_row<'a>(&'a self, layer: usize, t: usize,
                     scratch: &'a mut [f32]) -> &'a [f32] {
        match &self.backing {
            KvBacking::Slab(store) => store.k_row(layer, t, scratch),
            KvBacking::Paged { pages, page_tokens } => pages[t / *page_tokens]
                .store
                .k_row(layer, t % *page_tokens, scratch),
        }
    }

    /// V row at (layer, t); see [`KvSlot::k_row`].
    pub fn v_row<'a>(&'a self, layer: usize, t: usize,
                     scratch: &'a mut [f32]) -> &'a [f32] {
        match &self.backing {
            KvBacking::Slab(store) => store.v_row(layer, t, scratch),
            KvBacking::Paged { pages, page_tokens } => pages[t / *page_tokens]
                .store
                .v_row(layer, t % *page_tokens, scratch),
        }
    }

    /// Borrow the raw f32 K row (F32 storage only — Int8 rows have no
    /// f32 representation to borrow; use [`KvSlot::k_row`]).
    #[inline]
    pub fn k_at(&self, layer: usize, t: usize) -> &[f32] {
        match &self.backing {
            KvBacking::Slab(store) => store.k_at(layer, t),
            KvBacking::Paged { pages, page_tokens } => {
                pages[t / *page_tokens].store.k_at(layer, t % *page_tokens)
            }
        }
    }

    /// Borrow the raw f32 V row (F32 storage only); see [`KvSlot::k_at`].
    #[inline]
    pub fn v_at(&self, layer: usize, t: usize) -> &[f32] {
        match &self.backing {
            KvBacking::Slab(store) => store.v_at(layer, t),
            KvBacking::Paged { pages, page_tokens } => {
                pages[t / *page_tokens].store.v_at(layer, t % *page_tokens)
            }
        }
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn attn_dim(&self) -> usize {
        self.attn_dim
    }

    fn reset(&mut self) {
        self.len = 0; // stale K/V rows are overwritten before reads
    }

    /// Number of pages currently mapped (0 for slab slots).
    pub fn pages_mapped(&self) -> usize {
        match &self.backing {
            KvBacking::Slab(_) => 0,
            KvBacking::Paged { pages, .. } => pages.len(),
        }
    }

    /// Host bytes of this slot's backing storage. Paged slots report
    /// the storage their table references; shared pages are counted in
    /// every referencing slot (the pool-level
    /// [`KvCachePool::host_slab_bytes`] counts each page once).
    pub fn host_bytes(&self) -> usize {
        match &self.backing {
            KvBacking::Slab(store) => store.host_bytes(),
            KvBacking::Paged { pages, .. } => {
                pages.iter().map(|p| p.store.host_bytes()).sum()
            }
        }
    }
}

/// What [`KvCachePool::admit`] grants: the session's slot plus the
/// number of leading prompt tokens whose KV was mapped from the prefix
/// index (prefill resumes at `cached_tokens`; 0 on the slab layout or
/// a prefix miss).
#[derive(Clone, Copy, Debug)]
pub struct AdmitInfo {
    pub slot: usize,
    pub cached_tokens: usize,
}

/// Counters for the paged allocator (all zero on the slab layout).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagedStats {
    /// admissions that mapped >= 1 page from the prefix index
    pub prefix_hits: u64,
    /// admissions that looked for a prefix and found none
    pub prefix_misses: u64,
    /// prompt tokens whose prefill was skipped via mapped pages
    pub prefix_tokens_reused: u64,
    /// shared pages privatized before a write
    pub cow_copies: u64,
    /// pages popped from the free list for new capacity
    pub page_faults: u64,
    /// prefix-index entries evicted under page pressure / cap
    pub prefix_evictions: u64,
    /// admissions that mapped a verified token span *below* page
    /// granularity (the longest common prefix inside the first
    /// differing page, copied into a private page)
    pub prefix_subpage_hits: u64,
    /// prompt tokens whose prefill was skipped via sub-page spans
    /// (disjoint from `prefix_tokens_reused`, which counts whole
    /// mapped pages)
    pub prefix_subpage_tokens: u64,
    /// compaction passes run ([`KvCachePool::compact`])
    pub compactions: u64,
    /// pages compaction returned to the free list
    pub pages_reclaimed: u64,
}

/// A published prefix: the page holding KV for `tokens`
/// (`tokens.len() == (depth+1) * page_tokens`), verified on lookup so
/// an FNV collision can never alias two different prefixes.
struct PrefixEntry {
    page: Arc<KvPage>,
    tokens: Vec<i32>,
    last_used: u64,
    /// admissions that mapped this entry — an entry still at 0 is
    /// published-but-never-reused, i.e. pinned bytes GC could reclaim
    hits: u64,
}

/// Paged-layout state: the page free list, the prefix index, and the
/// accounting the report/fuzz layers read.
struct PagedState {
    free: Vec<Arc<KvPage>>,
    page_tokens: usize,
    pages_total: usize,
    pages_peak: usize,
    /// rolling-hash -> published prefix page (chained: depth-q lookup
    /// key is the hash of the first `q * page_tokens` tokens)
    prefix: HashMap<u64, PrefixEntry>,
    stats: PagedStats,
    /// logical clock for prefix-index LRU
    clock: u64,
    /// modeled deployment bytes of one page (paper arch at the pool's
    /// precision); feeds the bytes-saved line
    modeled_page_bytes: f64,
    /// compaction trigger policy (scheduler reads it; the pool itself
    /// only compacts when told to)
    compact: CompactMode,
    /// sub-page prefix matching/publishing enabled (on whenever
    /// `compact` is, or forced via `set_subpage_prefix`)
    subpage: bool,
    /// `clock` at the end of the previous compaction pass — the stale
    /// sweep's grace window: a single-referenced prefix entry is only
    /// evicted if it was not used since the last pass
    last_compact_clock: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a rolling FNV-1a hash over a token span.
fn extend_hash(mut h: u64, tokens: &[i32]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Upper bound on retained prefix entries (beyond it, publishing
/// evicts the least-recently-used evictable entry first).
pub const PREFIX_INDEX_CAP: usize = 512;

/// Drop a page reference, returning the page to the free list iff this
/// was the last reference. Every page-table / prefix-index drop routes
/// through here, which is what keeps `free + used == total` an
/// invariant rather than a hope (a CoW-replaced or unmapped page whose
/// Arc is still held elsewhere stays "used" and is reclaimed by
/// whichever holder drops it last).
fn retire(free: &mut Vec<Arc<KvPage>>, page: Arc<KvPage>) {
    if Arc::strong_count(&page) == 1 {
        free.push(page);
    }
}

/// Pop a free page, evicting least-recently-used single-referenced
/// prefix entries under pressure. `None` means genuinely out of pages
/// (every page is mapped by a live session or a still-shared prefix).
fn take_free_page(paged: &mut PagedState) -> Option<Arc<KvPage>> {
    if let Some(p) = paged.free.pop() {
        return Some(p);
    }
    let victim = paged
        .prefix
        .iter()
        .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
        .min_by_key(|(k, e)| (e.last_used, **k))
        .map(|(k, _)| *k)?;
    let e = paged.prefix.remove(&victim).expect("victim key vanished");
    paged.stats.prefix_evictions += 1;
    Some(e.page)
}

/// Number of prefix entries whose page would be reclaimable if evicted
/// (only the index references it).
fn evictable_prefix_pages(paged: &PagedState) -> usize {
    paged
        .prefix
        .values()
        .filter(|e| Arc::strong_count(&e.page) == 1)
        .count()
}

/// Fixed-capacity pool of [`KvSlot`]s with a free list; in the paged
/// layout also the page allocator and prefix index.
pub struct KvCachePool {
    slots: Vec<KvSlot>,
    free: Vec<usize>,
    precision: KvPrecision,
    layout: KvLayout,
    /// reusable aliasing bitmap for `slots_mut_many` (cleared per
    /// call; kept here so the batched decode step allocates nothing
    /// for the check)
    seen: Vec<bool>,
    /// modeled deployment bytes one max-length session pins (paper
    /// arch, at the pool's KV precision)
    modeled_bytes_per_session: f64,
    /// modeled deployment budget in bytes
    modeled_budget_bytes: f64,
    peak_in_use: usize,
    paged: Option<PagedState>,
}

/// Hard host-side cap on preallocated slots, independent of how large
/// the modeled device headroom is (keeps the simulator's RSS bounded).
pub const MAX_HOST_SLOTS: usize = 1024;

impl KvCachePool {
    /// Size a slab pool from the modeled deployment: `budget_gb` of KV
    /// headroom on the target device (see `memory::serve_kv_budget_gb`)
    /// divided by the per-session KV bytes of the paper-scale
    /// architecture at this pruning rate *and KV precision* — int8 KV
    /// packs ~3.8x more sessions into the same budget. Host slots are
    /// shaped by the *served* (simulator) model config and capped at
    /// `host_slot_cap` — the scheduler's reachable concurrency — so a
    /// huge modeled headroom doesn't preallocate megabytes of slab no
    /// session can ever touch. (Layout-aware sizing lives in
    /// [`KvCachePool::for_budget_layout`]; this is the slab shorthand.)
    #[allow(clippy::too_many_arguments)]
    pub fn for_budget(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        paper_cfg: &ModelConfig,
        rate_pct: u32,
        max_seq: usize,
        precision: KvPrecision,
        budget_gb: f64,
        host_slot_cap: usize,
    ) -> Result<KvCachePool> {
        Self::for_budget_layout(
            host_cfg,
            host_attn_dim,
            paper_cfg,
            rate_pct,
            max_seq,
            precision,
            budget_gb,
            host_slot_cap,
            KvLayout::Slab,
            0,
        )
    }

    /// Layout-aware budget sizing. Slab divides the budget into
    /// worst-case `max_seq` reservations; **paged divides it into
    /// pages** (`memory::kv_page_bytes`), so admission capacity is the
    /// page budget and short sessions stop paying for slack they never
    /// touch — the same budget that slabs 6 max-length sessions pages
    /// out to `6 * max_seq / page_tokens` pages, each short session
    /// takes only the pages its prompt needs, and strictly more of
    /// them are admitted (asserted >= 2x in
    /// `paged_budget_admits_2x_short_sessions`).
    #[allow(clippy::too_many_arguments)]
    pub fn for_budget_layout(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        paper_cfg: &ModelConfig,
        rate_pct: u32,
        max_seq: usize,
        precision: KvPrecision,
        budget_gb: f64,
        host_slot_cap: usize,
        layout: KvLayout,
        page_tokens: usize,
    ) -> Result<KvCachePool> {
        let per_session = memory::kv_bytes_per_session_at(
            paper_cfg,
            rate_pct,
            max_seq,
            precision.modeled_bytes_per_elem(),
        );
        let budget_bytes = budget_gb * 1e9;
        match layout {
            KvLayout::Slab => {
                let n = (budget_bytes / per_session).floor() as usize;
                if n == 0 {
                    bail!(
                        "KV budget {budget_gb:.3} GB holds zero sessions \
                         ({:.1} MB each at max_seq {max_seq}, {} KV) — raise \
                         --kv-budget-gb, lower --max-seq, or drop --kv-bits",
                        per_session / 1e6,
                        precision.label()
                    );
                }
                Ok(Self::with_slots(
                    host_cfg,
                    host_attn_dim,
                    n.min(MAX_HOST_SLOTS).min(host_slot_cap.max(1)),
                    max_seq,
                    precision,
                    per_session,
                    budget_bytes,
                ))
            }
            KvLayout::Paged => {
                let pt = page_tokens.clamp(1, max_seq.max(1));
                let page_bytes = memory::kv_page_bytes(
                    paper_cfg,
                    rate_pct,
                    pt,
                    precision.modeled_bytes_per_elem(),
                );
                let total_pages =
                    (budget_bytes / page_bytes).floor() as usize;
                if total_pages == 0 {
                    bail!(
                        "KV budget {budget_gb:.3} GB holds zero pages \
                         ({:.2} MB each at page_tokens {pt}, {} KV) — \
                         raise --kv-budget-gb or lower --page-tokens",
                        page_bytes / 1e6,
                        precision.label()
                    );
                }
                // a session needs >= 1 page, so the page budget bounds
                // concurrency; host slots stay capped like slab
                let n_slots = total_pages
                    .min(MAX_HOST_SLOTS)
                    .min(host_slot_cap.max(1));
                // host pages: what resident sessions can actually
                // touch plus one session of headroom so released
                // prefixes can be retained rather than evicted
                let pages_per_session = max_seq.div_ceil(pt);
                let host_pages = total_pages
                    .min(n_slots * pages_per_session + pages_per_session);
                Ok(Self::with_slots_layout(
                    host_cfg,
                    host_attn_dim,
                    n_slots,
                    max_seq,
                    precision,
                    per_session,
                    budget_bytes,
                    KvLayout::Paged,
                    pt,
                    host_pages,
                ))
            }
        }
    }

    /// Direct slab construction with an explicit slot count (tests).
    pub fn with_slots(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        n_slots: usize,
        max_seq: usize,
        precision: KvPrecision,
        modeled_bytes_per_session: f64,
        modeled_budget_bytes: f64,
    ) -> KvCachePool {
        Self::with_slots_layout(
            host_cfg,
            host_attn_dim,
            n_slots,
            max_seq,
            precision,
            modeled_bytes_per_session,
            modeled_budget_bytes,
            KvLayout::Slab,
            0,
            0,
        )
    }

    /// Direct construction with explicit slot / page counts.
    /// `page_tokens` and `n_pages` are ignored for the slab layout;
    /// the paged modeled page bytes derive from
    /// `modeled_bytes_per_session` (a page is `page_tokens / max_seq`
    /// of a session, exactly — both are linear in token count).
    #[allow(clippy::too_many_arguments)]
    pub fn with_slots_layout(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        n_slots: usize,
        max_seq: usize,
        precision: KvPrecision,
        modeled_bytes_per_session: f64,
        modeled_budget_bytes: f64,
        layout: KvLayout,
        page_tokens: usize,
        n_pages: usize,
    ) -> KvCachePool {
        assert!(n_slots > 0);
        let (slots, paged) = match layout {
            KvLayout::Slab => {
                let slots: Vec<KvSlot> = (0..n_slots)
                    .map(|_| {
                        KvSlot::new_slab(host_cfg.n_layers, max_seq,
                                         host_attn_dim, precision)
                    })
                    .collect();
                (slots, None)
            }
            KvLayout::Paged => {
                let pt = page_tokens.clamp(1, max_seq.max(1));
                assert!(n_pages > 0, "paged layout needs >= 1 page");
                let slots: Vec<KvSlot> = (0..n_slots)
                    .map(|_| {
                        KvSlot::new_paged(max_seq, host_attn_dim,
                                          precision, pt)
                    })
                    .collect();
                let free: Vec<Arc<KvPage>> = (0..n_pages)
                    .rev()
                    .map(|id| {
                        Arc::new(KvPage {
                            id: id as u32,
                            store: KvStore::new(host_cfg.n_layers, pt,
                                                host_attn_dim, precision),
                        })
                    })
                    .collect();
                let modeled_page_bytes = modeled_bytes_per_session
                    * pt as f64
                    / max_seq.max(1) as f64;
                (
                    slots,
                    Some(PagedState {
                        free,
                        page_tokens: pt,
                        pages_total: n_pages,
                        pages_peak: 0,
                        prefix: HashMap::new(),
                        stats: PagedStats::default(),
                        clock: 0,
                        modeled_page_bytes,
                        compact: CompactMode::Off,
                        subpage: false,
                        last_compact_clock: 0,
                    }),
                )
            }
        };
        KvCachePool {
            slots,
            free: (0..n_slots).rev().collect(),
            precision,
            layout,
            seen: vec![false; n_slots],
            modeled_bytes_per_session,
            modeled_budget_bytes,
            peak_in_use: 0,
            paged,
        }
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    /// Tokens per page (0 on the slab layout).
    pub fn page_tokens(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| p.page_tokens)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Occupancy fraction in [0,1] of the scarce KV resource: pages on
    /// the paged layout (prefix-shared pages count once), slots on the
    /// slab layout. The brownout pressure signal.
    pub fn occupancy_frac(&self) -> f64 {
        match &self.paged {
            Some(p) if p.pages_total > 0 => {
                (p.pages_total - p.free.len()) as f64
                    / p.pages_total as f64
            }
            _ => self.in_use() as f64 / self.slots.len().max(1) as f64,
        }
    }

    /// Longest session this pool can hold: `max_seq`, additionally
    /// clamped by total page capacity on the paged layout (admission
    /// uses this so a request that could never be paged in is rejected
    /// up front rather than admitted and preempted forever).
    pub fn session_token_capacity(&self) -> usize {
        let max_seq = self.slots[0].max_seq;
        match &self.paged {
            None => max_seq,
            Some(p) => max_seq.min(p.pages_total * p.page_tokens),
        }
    }

    /// Modeled deployment bytes currently pinned at peak: whole-slab
    /// sessions for slab, actually-touched pages for paged (the point
    /// of the layout — short sessions stop pinning `max_seq` slack).
    pub fn modeled_peak_bytes(&self) -> f64 {
        match &self.paged {
            None => self.peak_in_use as f64 * self.modeled_bytes_per_session,
            Some(p) => p.pages_peak as f64 * p.modeled_page_bytes,
        }
    }

    pub fn modeled_budget_bytes(&self) -> f64 {
        self.modeled_budget_bytes
    }

    /// Host bytes of the whole preallocated KV arena (each page
    /// counted once, shared or not).
    pub fn host_slab_bytes(&self) -> usize {
        match &self.paged {
            None => self.slots.iter().map(|s| s.host_bytes()).sum(),
            Some(p) => {
                let per_page = p
                    .free
                    .first()
                    .map(|pg| pg.store.host_bytes())
                    .unwrap_or_else(|| {
                        // free list drained: measure via any mapped page
                        self.slots
                            .iter()
                            .find_map(|s| match &s.backing {
                                KvBacking::Paged { pages, .. } => {
                                    pages.first().map(|pg| pg.store.host_bytes())
                                }
                                KvBacking::Slab(_) => None,
                            })
                            .unwrap_or(0)
                    });
                p.pages_total * per_page
            }
        }
    }

    /// Claim a free slot; `None` when the budget is exhausted (callers
    /// queue or reject — see `admission.rs`). Prefer
    /// [`KvCachePool::admit`] on the serving path — it also maps
    /// shared prefix pages and gates on page availability.
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        self.slots[id].reset();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(id)
    }

    /// Admit a session for `prompt`: claim a slot, and on the paged
    /// layout map any published prefix pages into its table (the
    /// session's prefill then resumes at `cached_tokens`) and gate on
    /// page availability for the rest of the prompt — `None` either
    /// when no slot is free or when the prompt's remaining pages could
    /// not possibly be faulted in (callers keep the session queued).
    /// `use_prefix` should be false when the serving backend does not
    /// populate the native KV cache (the PJRT artifact path), since
    /// reusing pages it never wrote would skip real computation.
    pub fn admit(&mut self, prompt: &[i32], use_prefix: bool)
                 -> Option<AdmitInfo> {
        if self.paged.is_none() {
            return self
                .alloc()
                .map(|slot| AdmitInfo { slot, cached_tokens: 0 });
        }
        let id = self.free.pop()?;
        self.slots[id].reset();
        let paged = self.paged.as_mut().expect("paged state");
        let pt = paged.page_tokens;
        paged.clock += 1;
        let clock = paged.clock;
        let mut cached = 0usize;
        let mut sub_tokens = 0usize;
        if use_prefix && prompt.len() > 1 {
            // deepest published chain q*pt <= len-1: prefill must still
            // compute >= 1 token to produce the first logits
            let max_q = (prompt.len() - 1) / pt;
            let mut h = FNV_OFFSET;
            let mut matched: Vec<Arc<KvPage>> = Vec::new();
            for q in 1..=max_q {
                h = extend_hash(h, &prompt[(q - 1) * pt..q * pt]);
                match paged.prefix.get_mut(&h) {
                    Some(e) if e.tokens[..] == prompt[..q * pt] => {
                        e.last_used = clock;
                        e.hits += 1;
                        matched.push(Arc::clone(&e.page));
                    }
                    _ => break,
                }
            }
            cached = matched.len() * pt;
            if let KvBacking::Paged { pages, .. } =
                &mut self.slots[id].backing
            {
                *pages = matched;
            }
            self.slots[id].len = cached;
            // the chain is exhausted at a page boundary — with
            // sub-page matching on, look for the longest verified
            // token span *inside* the first differing page and copy
            // it into a private page so prefill resumes mid-page.
            // Any qualifying entry with the same span length holds
            // bit-identical rows (entries are token-verified and the
            // engine is deterministic), so the key tie-break only
            // pins the iteration-order-independent choice.
            if paged.subpage && cached + 1 < prompt.len() {
                let cap = prompt.len() - 1 - cached;
                let mut best: Option<(u64, usize)> = None;
                for (k, e) in paged.prefix.iter() {
                    if e.tokens.len() <= cached
                        || e.tokens.len() > cached + pt
                        || e.tokens[..cached] != prompt[..cached]
                    {
                        continue; // entry's page doesn't start at `cached`
                    }
                    let m = e.tokens[cached..]
                        .iter()
                        .zip(&prompt[cached..])
                        .take_while(|(a, b)| a == b)
                        .count()
                        .min(cap);
                    if m == 0 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some((bk, bm)) => m > bm || (m == bm && *k < bk),
                    };
                    if better {
                        best = Some((*k, m));
                    }
                }
                if let Some((key, m)) = best {
                    if let Some(mut fresh) = take_free_page(paged) {
                        let e = paged
                            .prefix
                            .get_mut(&key)
                            .expect("sub-page key vanished");
                        e.last_used = clock;
                        e.hits += 1;
                        Arc::get_mut(&mut fresh)
                            .expect("free page has one reference")
                            .store
                            .copy_rows_from(&e.page.store, m);
                        if let KvBacking::Paged { pages, .. } =
                            &mut self.slots[id].backing
                        {
                            pages.push(fresh);
                        }
                        cached += m;
                        sub_tokens = m;
                        self.slots[id].len = cached;
                    }
                }
            }
        }
        // pages-available gate: the rest of the prompt must be
        // faultable (free now, or reclaimable from retired prefixes)
        let needed = prompt
            .len()
            .div_ceil(pt)
            .saturating_sub(self.slots[id].pages_mapped());
        if paged.free.len() + evictable_prefix_pages(paged) < needed {
            // roll back: unmap, return the slot, let the caller queue
            if let KvBacking::Paged { pages, .. } =
                &mut self.slots[id].backing
            {
                for p in pages.drain(..) {
                    retire(&mut paged.free, p);
                }
            }
            self.slots[id].len = 0;
            self.free.push(id);
            return None;
        }
        if use_prefix {
            if cached > 0 {
                paged.stats.prefix_hits += 1;
                paged.stats.prefix_tokens_reused +=
                    (cached - sub_tokens) as u64;
            } else {
                paged.stats.prefix_misses += 1;
            }
            if sub_tokens > 0 {
                // the private sub-span copy popped a page
                paged.stats.page_faults += 1;
                paged.stats.prefix_subpage_hits += 1;
                paged.stats.prefix_subpage_tokens += sub_tokens as u64;
            }
        }
        paged.pages_peak = paged
            .pages_peak
            .max(paged.pages_total - paged.free.len());
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(AdmitInfo { slot: id, cached_tokens: cached })
    }

    /// Make positions `0..need` of slot `id` writable: on the paged
    /// layout, fault unmapped pages in from the free list and
    /// copy-on-write any page in the write range (`len..need`) that is
    /// still shared with another table or the prefix index. Errors
    /// when the pool is out of pages (serving preempts the session) or
    /// `need` exceeds `max_seq`. A no-op beyond the bounds check for
    /// slab slots, whose reservation is always whole and private.
    pub fn ensure_capacity(&mut self, id: usize, need: usize) -> Result<()> {
        let max_seq = self.slots[id].max_seq;
        ensure!(
            need <= max_seq,
            "session needs {need} tokens > max_seq {max_seq}"
        );
        let Some(paged) = self.paged.as_mut() else {
            return Ok(());
        };
        if need == 0 {
            return Ok(());
        }
        let pt = paged.page_tokens;
        let slot = &mut self.slots[id];
        let KvBacking::Paged { pages, .. } = &mut slot.backing else {
            unreachable!("paged pool with slab slot");
        };
        // pages the upcoming writes (positions len..need) can touch;
        // everything below stays read-only and may remain shared
        let first_write_page = slot.len / pt;
        let last_page = (need - 1) / pt;
        for idx in 0..=last_page {
            if idx >= pages.len() {
                let Some(page) = take_free_page(paged) else {
                    bail!(
                        "out of KV pages: slot {id} needs page {idx} \
                         ({} total, all referenced)",
                        paged.pages_total
                    );
                };
                pages.push(page);
                paged.stats.page_faults += 1;
            } else if idx >= first_write_page
                && Arc::strong_count(&pages[idx]) > 1
            {
                // copy-on-write: privatize before the write reaches it
                let Some(mut fresh) = take_free_page(paged) else {
                    bail!(
                        "out of KV pages: slot {id} cannot privatize \
                         shared page {idx} ({} total, all referenced)",
                        paged.pages_total
                    );
                };
                Arc::get_mut(&mut fresh)
                    .expect("free page has one reference")
                    .store
                    .copy_from(&pages[idx].store);
                let old = std::mem::replace(&mut pages[idx], fresh);
                retire(&mut paged.free, old);
                paged.stats.cow_copies += 1;
            }
        }
        paged.pages_peak = paged
            .pages_peak
            .max(paged.pages_total - paged.free.len());
        Ok(())
    }

    /// Publish slot `id`'s fully-computed prompt pages into the prefix
    /// index so later sessions sharing the prefix skip prefill for it.
    /// Only *full* pages wholly inside the prompt are published — the
    /// owner's decode writes start at `prompt.len()`, so a published
    /// page is never rewritten by its owner, and copy-on-write covers
    /// everyone else. A no-op on the slab layout or while the prompt is
    /// not fully cached. Callers on non-native backends (which never
    /// write the KV cache) must not publish.
    pub fn publish_prefix(&mut self, id: usize, prompt: &[i32]) {
        let Some(paged) = self.paged.as_mut() else { return };
        let slot = &self.slots[id];
        if slot.len < prompt.len() {
            return;
        }
        let pt = paged.page_tokens;
        let n_full = prompt.len() / pt;
        paged.clock += 1;
        let clock = paged.clock;
        let mut h = FNV_OFFSET;
        for idx in 0..n_full {
            h = extend_hash(h, &prompt[idx * pt..(idx + 1) * pt]);
            let KvBacking::Paged { pages, .. } = &slot.backing else {
                unreachable!("paged pool with slab slot");
            };
            let page = &pages[idx];
            if let Some(e) = paged.prefix.get_mut(&h) {
                if e.tokens[..] == prompt[..(idx + 1) * pt] {
                    e.last_used = clock;
                }
                // hash collision with a different prefix: keep the
                // incumbent (verification makes collisions harmless)
                continue;
            }
            if paged.prefix.len() >= PREFIX_INDEX_CAP {
                let victim = paged
                    .prefix
                    .iter()
                    .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
                    .min_by_key(|(k, e)| (e.last_used, **k))
                    .map(|(k, _)| *k);
                let Some(victim) = victim else { continue };
                let e = paged.prefix.remove(&victim).expect("victim");
                paged.stats.prefix_evictions += 1;
                retire(&mut paged.free, e.page);
            }
            paged.prefix.insert(
                h,
                PrefixEntry {
                    page: Arc::clone(page),
                    tokens: prompt[..(idx + 1) * pt].to_vec(),
                    last_used: clock,
                    hits: 0,
                },
            );
        }
        // sub-page tail: with matching enabled, publish the partial
        // last prompt page too, so prompts sharing a prefix shorter
        // than one page (or diverging mid-page) can still resume. The
        // live tail page itself cannot be shared — its owner keeps
        // writing decode rows into it — so the span is copied into an
        // index-owned page (skipped under page exhaustion; compaction
        // reclaims these once they go stale).
        let tail = prompt.len() - n_full * pt;
        if paged.subpage && tail > 0 {
            let h_tail = extend_hash(h, &prompt[n_full * pt..]);
            if let Some(e) = paged.prefix.get_mut(&h_tail) {
                if e.tokens[..] == prompt[..] {
                    e.last_used = clock;
                }
                // hash collision with a different span: keep the
                // incumbent (verification makes collisions harmless)
                return;
            }
            if paged.prefix.len() >= PREFIX_INDEX_CAP {
                let victim = paged
                    .prefix
                    .iter()
                    .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
                    .min_by_key(|(k, e)| (e.last_used, **k))
                    .map(|(k, _)| *k);
                let Some(victim) = victim else { return };
                let e = paged.prefix.remove(&victim).expect("victim");
                paged.stats.prefix_evictions += 1;
                retire(&mut paged.free, e.page);
            }
            let Some(mut fresh) = take_free_page(paged) else { return };
            let KvBacking::Paged { pages, .. } = &slot.backing else {
                unreachable!("paged pool with slab slot");
            };
            Arc::get_mut(&mut fresh)
                .expect("free page has one reference")
                .store
                .copy_rows_from(&pages[n_full].store, tail);
            paged.pages_peak = paged
                .pages_peak
                .max(paged.pages_total - paged.free.len());
            paged.prefix.insert(
                h_tail,
                PrefixEntry {
                    page: fresh,
                    tokens: prompt.to_vec(),
                    last_used: clock,
                    hits: 0,
                },
            );
        }
    }

    /// Drop every prefix-index entry, reclaiming pages only the index
    /// still references (drain / shutdown path; also the fuzz suite's
    /// full-reclamation lever).
    pub fn clear_prefix_index(&mut self) {
        let Some(paged) = self.paged.as_mut() else { return };
        for (_, e) in paged.prefix.drain() {
            retire(&mut paged.free, e.page);
        }
    }

    /// Enable compaction (also flips sub-page prefix matching on when
    /// the mode is enabled — see [`CompactMode`]). No-op on slab.
    pub fn set_compact_mode(&mut self, mode: CompactMode) {
        if let Some(p) = self.paged.as_mut() {
            p.compact = mode;
            if mode.enabled() {
                p.subpage = true;
            }
        }
    }

    /// The pool's compaction trigger policy (the scheduler reads this;
    /// the pool itself only compacts when [`KvCachePool::compact`] is
    /// called). `Off` on slab.
    pub fn compact_mode(&self) -> CompactMode {
        self.paged.as_ref().map_or(CompactMode::Off, |p| p.compact)
    }

    /// Force sub-page prefix matching independently of the compaction
    /// mode (tests and the fuzz harness).
    pub fn set_subpage_prefix(&mut self, on: bool) {
        if let Some(p) = self.paged.as_mut() {
            p.subpage = on;
        }
    }

    /// Stranded token slots: unused tail capacity of partially-filled
    /// *private* tail pages (a shared tail still serves its other
    /// holders, so its slack is not this slot's to reclaim).
    /// Recomputed from scratch on every call — the fuzz suite holds
    /// this to an independent recount after every event.
    pub fn frag_slots(&self) -> usize {
        let Some(paged) = self.paged.as_ref() else { return 0 };
        let pt = paged.page_tokens;
        self.slots
            .iter()
            .map(|s| match &s.backing {
                KvBacking::Paged { pages, .. } => {
                    if s.len == 0 || s.len % pt == 0 {
                        return 0;
                    }
                    match pages.get(s.len / pt) {
                        Some(p) if Arc::strong_count(p) == 1 => {
                            pt - s.len % pt
                        }
                        _ => 0,
                    }
                }
                KvBacking::Slab(_) => 0,
            })
            .sum()
    }

    /// Dead pages: page-table entries wholly beyond their slot's live
    /// length (rewind leftovers) plus pages held only by the LRU
    /// prefix index.
    pub fn frag_pages(&self) -> usize {
        let Some(paged) = self.paged.as_ref() else { return 0 };
        let pt = paged.page_tokens;
        let stale: usize = self
            .slots
            .iter()
            .map(|s| match &s.backing {
                KvBacking::Paged { pages, .. } => {
                    pages.len().saturating_sub(s.len.div_ceil(pt))
                }
                KvBacking::Slab(_) => 0,
            })
            .sum();
        stale + evictable_prefix_pages(paged)
    }

    /// Fragmentation fraction of the page pool in [0,1]: dead pages
    /// plus stranded tail slack (in page units) over total pages —
    /// the `--compact thresh=P` trigger signal.
    pub fn frag_frac(&self) -> f64 {
        let Some(paged) = self.paged.as_ref() else { return 0.0 };
        if paged.pages_total == 0 {
            return 0.0;
        }
        (self.frag_pages() as f64
            + self.frag_slots() as f64 / paged.page_tokens as f64)
            / paged.pages_total as f64
    }

    /// One compaction pass. For each `(slot id, inject_fault)` pair:
    ///
    /// 1. unmap page-table entries wholly beyond the live length
    ///    (rewind leftovers) — sole references return to the free
    ///    list immediately;
    /// 2. if the partial tail page is shared, migrate its live rows
    ///    into a fresh private page via a byte-exact copy — the
    ///    shared original is **never written in place** — so its
    ///    remaining holders (typically just the prefix index) become
    ///    the only ones and the stale sweep below can reclaim it.
    ///
    /// Then sweep the prefix index: single-referenced entries not
    /// used since the previous pass (one grace window, so a freshly
    /// published prefix always survives at least one pass) are
    /// evicted and their pages retired.
    ///
    /// A `true` beside a slot id injects a `compact_move` fault: that
    /// slot's migration aborts *before* any table change, the id is
    /// reported in [`CompactReport::failed`], and the pass moves on —
    /// callers quarantine the session while every other slot compacts
    /// normally. Token payloads are never altered (migration copies
    /// bytes verbatim), so decode stays bit-identical to the slab
    /// oracle across any interleaving of passes and steps.
    pub fn compact(&mut self, ids: &[(usize, bool)]) -> CompactReport {
        let mut report = CompactReport::default();
        let Some(paged) = self.paged.as_mut() else { return report };
        let free_before = paged.free.len();
        let pt = paged.page_tokens;
        for &(id, fail_move) in ids {
            let slot = &mut self.slots[id];
            let KvBacking::Paged { pages, .. } = &mut slot.backing
            else {
                continue;
            };
            // 1. dead tables beyond the live tail
            let live_pages = slot.len.div_ceil(pt);
            while pages.len() > live_pages {
                let p = pages.pop().expect("len checked");
                retire(&mut paged.free, p);
            }
            // 2. shared partial tail -> private dense page
            let within = slot.len % pt;
            if within == 0 || live_pages == 0 {
                continue;
            }
            let tail = live_pages - 1;
            if tail >= pages.len()
                || Arc::strong_count(&pages[tail]) == 1
            {
                continue;
            }
            if fail_move {
                report.failed.push(id);
                continue;
            }
            let Some(mut fresh) = take_free_page(paged) else {
                continue; // out of pages: migration can't help now
            };
            Arc::get_mut(&mut fresh)
                .expect("free page has one reference")
                .store
                .copy_rows_from(&pages[tail].store, within);
            let old = std::mem::replace(&mut pages[tail], fresh);
            retire(&mut paged.free, old);
            report.migrated += 1;
        }
        // stale prefix sweep: evictable entries idle for one full
        // compaction window
        let cutoff = paged.last_compact_clock;
        let stale: Vec<u64> = paged
            .prefix
            .iter()
            .filter(|(_, e)| {
                Arc::strong_count(&e.page) == 1 && e.last_used <= cutoff
            })
            .map(|(k, _)| *k)
            .collect();
        for k in stale {
            let e = paged.prefix.remove(&k).expect("stale key");
            paged.stats.prefix_evictions += 1;
            retire(&mut paged.free, e.page);
        }
        paged.last_compact_clock = paged.clock;
        report.pages_reclaimed =
            paged.free.len().saturating_sub(free_before);
        paged.stats.compactions += 1;
        paged.stats.pages_reclaimed += report.pages_reclaimed as u64;
        report
    }

    /// Return a slot to the free list. On the paged layout its page
    /// table is unmapped — pages nobody else references go back to the
    /// page free list; pages shared with other tables or the prefix
    /// index stay resident for their remaining holders.
    pub fn release(&mut self, id: usize) {
        debug_assert!(!self.free.contains(&id), "double release of {id}");
        if let (Some(paged), KvBacking::Paged { pages, .. }) =
            (self.paged.as_mut(), &mut self.slots[id].backing)
        {
            for p in pages.drain(..) {
                retire(&mut paged.free, p);
            }
        }
        self.slots[id].reset();
        self.free.push(id);
    }

    pub fn slot(&self, id: usize) -> &KvSlot {
        &self.slots[id]
    }

    pub fn slot_mut(&mut self, id: usize) -> &mut KvSlot {
        &mut self.slots[id]
    }

    // ---- paged introspection (report + fuzz/parity test surface) ----

    /// Total preallocated pages (0 on slab).
    pub fn pages_total(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| p.pages_total)
    }

    /// Pages currently on the free list (0 on slab).
    pub fn pages_free(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| p.free.len())
    }

    /// Pages currently referenced by >= 1 page table or prefix entry.
    pub fn pages_used(&self) -> usize {
        self.paged
            .as_ref()
            .map_or(0, |p| p.pages_total - p.free.len())
    }

    /// High-water mark of `pages_used` (0 on slab).
    pub fn pages_peak(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| p.pages_peak)
    }

    /// Prefix-cache / allocator counters (all zero on slab).
    pub fn paged_stats(&self) -> PagedStats {
        self.paged.as_ref().map_or_else(PagedStats::default, |p| p.stats)
    }

    /// Live prefix-index entries.
    pub fn prefix_index_len(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| p.prefix.len())
    }

    /// Prefix-index entries published but never re-hit by a later
    /// admission — the GC candidates: they pin a page each without
    /// having saved any prefill yet (0 on slab).
    pub fn prefix_idle_entries(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| {
            p.prefix.values().filter(|e| e.hits == 0).count()
        })
    }

    /// Host bytes pinned by never-re-hit prefix entries (each idle
    /// entry holds one page; 0 on slab). The `kv.prefix_idle_bytes`
    /// gauge in the metrics snapshot.
    pub fn prefix_idle_bytes(&self) -> usize {
        self.paged.as_ref().map_or(0, |p| {
            p.prefix
                .values()
                .filter(|e| e.hits == 0)
                .map(|e| e.page.store.host_bytes())
                .sum()
        })
    }

    /// Modeled deployment bytes saved by prefix reuse so far — whole
    /// mapped pages plus sub-page spans, at the modeled per-token KV
    /// cost (`modeled_page_bytes / page_tokens`, which equals
    /// `memory::kv_token_bytes` at the pool's precision).
    pub fn prefix_bytes_saved_modeled(&self) -> f64 {
        self.paged.as_ref().map_or(0.0, |p| {
            (p.stats.prefix_tokens_reused
                + p.stats.prefix_subpage_tokens) as f64
                * p.modeled_page_bytes
                / p.page_tokens as f64
        })
    }

    /// (page id, Arc strong count) for every page mapped by slot `id`,
    /// in table order. Empty on slab.
    pub fn slot_page_refs(&self, id: usize) -> Vec<(u32, usize)> {
        match &self.slots[id].backing {
            KvBacking::Slab(_) => Vec::new(),
            KvBacking::Paged { pages, .. } => pages
                .iter()
                .map(|p| (p.id, Arc::strong_count(p)))
                .collect(),
        }
    }

    /// (page id, Arc strong count) for every prefix-index entry.
    pub fn prefix_page_refs(&self) -> Vec<(u32, usize)> {
        self.paged.as_ref().map_or_else(Vec::new, |pg| {
            pg.prefix
                .values()
                .map(|e| (e.page.id, Arc::strong_count(&e.page)))
                .collect()
        })
    }

    /// Page ids on the free list.
    pub fn free_page_ids(&self) -> Vec<u32> {
        self.paged
            .as_ref()
            .map_or_else(Vec::new, |p| p.free.iter().map(|pg| pg.id).collect())
    }

    /// Mutably borrow several distinct slots at once — the batched
    /// decode step (`engine::Engine::step_batch`) updates every active
    /// session's cache within one fused pass. Errors if any id is out
    /// of range or repeated (repetition would alias `&mut`s). The
    /// returned `Vec` of borrows is the one per-step allocation on the
    /// decode hot path (a reusable buffer of references is not
    /// expressible — its lifetime changes per call); the aliasing
    /// bitmap is pool-owned scratch.
    pub fn slots_mut_many<'a>(&'a mut self, ids: &[usize])
                              -> Result<Vec<&'a mut KvSlot>> {
        let n = self.slots.len();
        self.seen.fill(false);
        for &id in ids {
            ensure!(id < n, "slot {id} out of range ({n} slots)");
            ensure!(!self.seen[id],
                    "slot {id} requested twice in one batch");
            self.seen[id] = true;
        }
        // validation complete: from here on, nothing touches `self`
        // except through the raw pointer below
        let base = self.slots.as_mut_ptr();
        let mut out: Vec<&'a mut KvSlot> = Vec::with_capacity(ids.len());
        for &id in ids {
            // SAFETY: `id < n` keeps the pointer in-bounds of the
            // `slots` allocation, and the `seen` pass above guarantees
            // ids are pairwise distinct, so each `&mut` refers to a
            // different element and none alias. No other access to
            // `self` interleaves while these borrows exist, and they
            // all carry lifetime 'a tied to `&'a mut self`, so the Vec
            // cannot outlive (or race with) the pool borrow.
            out.push(unsafe { &mut *base.add(id) });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BitConfig, QuantFormat};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn pool_p(n: usize, precision: KvPrecision) -> KvCachePool {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = cfg.pruned(0).attn_dim(&cfg);
        KvCachePool::with_slots(&cfg, a, n, 16, precision, 1e6,
                                n as f64 * 1e6)
    }

    fn pool(n: usize) -> KvCachePool {
        pool_p(n, KvPrecision::F32)
    }

    /// Paged pool: `n` slots, page size 4, `n_pages` pages, max_seq 16.
    fn paged_pool(n: usize, n_pages: usize,
                  precision: KvPrecision) -> KvCachePool {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = cfg.pruned(0).attn_dim(&cfg);
        KvCachePool::with_slots_layout(&cfg, a, n, 16, precision, 1e6,
                                       n as f64 * 1e6, KvLayout::Paged,
                                       4, n_pages)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        assert!(p.alloc().is_none(), "over-allocation must fail");
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "free list reuses the released slot");
        assert_eq!(p.peak_in_use(), 2);
    }

    #[test]
    fn released_slot_is_reset() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let (k, v) = (vec![1.0; a], vec![2.0; a]);
        p.slot_mut(id).write(0, 0, &k, &v);
        p.slot_mut(id).advance_to(1);
        assert_eq!(p.slot(id).len, 1);
        p.release(id);
        let id2 = p.alloc().unwrap();
        assert_eq!(p.slot(id2).len, 0);
    }

    #[test]
    fn slot_rows_roundtrip() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let k: Vec<f32> = (0..a).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..a).map(|i| -(i as f32)).collect();
        p.slot_mut(id).write(1, 3, &k, &v);
        assert_eq!(p.slot(id).k_at(1, 3), &k[..]);
        assert_eq!(p.slot(id).v_at(1, 3), &v[..]);
        // other positions untouched
        assert!(p.slot(id).k_at(1, 2).iter().all(|&x| x == 0.0));
        // the precision-generic accessors agree with the raw slices
        let mut scratch = vec![0.0f32; a];
        assert_eq!(p.slot(id).k_row(1, 3, &mut scratch), &k[..]);
        assert_eq!(p.slot(id).v_row(1, 3, &mut scratch), &v[..]);
    }

    #[test]
    fn paged_rows_match_slab_rows_bitwise() {
        // the layout only changes *where* a row lives, never its
        // value: writes through a page table read back == a slab's
        let mut ps = pool_p(1, KvPrecision::Int8);
        let mut pp = paged_pool(1, 8, KvPrecision::Int8);
        let slab = ps.alloc().unwrap();
        let paged = pp.admit(&[1, 2, 3], true).unwrap().slot;
        pp.ensure_capacity(paged, 11).unwrap();
        let a = ps.slot(slab).attn_dim;
        let mut rng = Rng::new(7);
        let mut s1 = vec![0.0f32; a];
        let mut s2 = vec![0.0f32; a];
        for t in 0..11 {
            // positions 0..11 straddle pages 0, 1 and 2 at pt=4
            let k = Tensor::randn(&[1, a], 1.0, &mut rng);
            let v = Tensor::randn(&[1, a], 1.0, &mut rng);
            for l in 0..2 {
                ps.slot_mut(slab).write(l, t, k.row(0), v.row(0));
                pp.slot_mut(paged).write(l, t, k.row(0), v.row(0));
            }
        }
        for t in 0..11 {
            for l in 0..2 {
                assert_eq!(ps.slot(slab).k_row(l, t, &mut s1),
                           pp.slot(paged).k_row(l, t, &mut s2));
                assert_eq!(ps.slot(slab).v_row(l, t, &mut s1),
                           pp.slot(paged).v_row(l, t, &mut s2));
            }
        }
        assert_eq!(pp.slot(paged).pages_mapped(), 3);
    }

    #[test]
    fn int8_roundtrip_within_quant_bound() {
        // property sweep: random K/V rows must come back within the
        // analytic bound `quant::roundtrip_error_bound` predicts for
        // blockwise int8 absmax quantization
        let mut p = pool_p(1, KvPrecision::Int8);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let mut rng = Rng::new(321);
        let mut scratch = vec![0.0f32; a];
        for trial in 0..40 {
            let layer = rng.below(2);
            let t = rng.below(16);
            let scale = rng.uniform_in(0.01, 8.0);
            let k = Tensor::randn(&[1, a], scale, &mut rng);
            let v = Tensor::randn(&[1, a], scale, &mut rng);
            p.slot_mut(id).write(layer, t, k.row(0), v.row(0));
            let bk = quant::roundtrip_error_bound(&k, QuantFormat::Int8);
            let bv = quant::roundtrip_error_bound(&v, QuantFormat::Int8);
            let kr = p.slot(id).k_row(layer, t, &mut scratch).to_vec();
            for (x, y) in k.row(0).iter().zip(&kr) {
                assert!((x - y).abs() <= bk,
                        "trial {trial}: k err {} > {bk}", (x - y).abs());
            }
            let vr = p.slot(id).v_row(layer, t, &mut scratch).to_vec();
            for (x, y) in v.row(0).iter().zip(&vr) {
                assert!((x - y).abs() <= bv,
                        "trial {trial}: v err {} > {bv}", (x - y).abs());
            }
        }
    }

    #[test]
    fn int8_slab_at_least_3p5x_smaller_than_f32() {
        let pf = pool_p(4, KvPrecision::F32);
        let pi = pool_p(4, KvPrecision::Int8);
        assert_eq!(pf.capacity(), pi.capacity());
        let ratio =
            pf.host_slab_bytes() as f64 / pi.host_slab_bytes() as f64;
        assert!(ratio >= 3.5, "int8 KV slab only {ratio:.2}x smaller");
        // per-slot view agrees
        let rs = pf.slot(0).host_bytes() as f64
            / pi.slot(0).host_bytes() as f64;
        assert!(rs >= 3.5, "per-slot ratio {rs:.2}");
    }

    #[test]
    fn int8_budget_admits_at_least_2x_sessions() {
        // the --kv-bits acceptance criterion: same modeled budget,
        // >= 2x the concurrent sessions at int8 (the analytic ratio is
        // ~3.76x; MAX_HOST_SLOTS and the slot cap must not mask it)
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per_f32 = memory::kv_bytes_per_session(&paper, 20, 64);
        let gb = 6.0 * per_f32 / 1e9 + 1e-12;
        let pf = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                         KvPrecision::F32, gb, 512)
            .unwrap();
        let pi = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                         KvPrecision::Int8, gb, 512)
            .unwrap();
        assert_eq!(pf.capacity(), 6);
        assert!(
            pi.capacity() >= 2 * pf.capacity(),
            "int8 admitted {} vs f32 {}",
            pi.capacity(),
            pf.capacity()
        );
    }

    #[test]
    fn paged_budget_admits_2x_short_sessions() {
        // the --kv-layout acceptance criterion: slab sizing reserves a
        // worst-case max_seq slab per session, so a budget holding 6
        // max-length sessions admits exactly 6 no matter how short the
        // prompts are. The same budget in pages admits one short
        // session per page — >= 2x more (here 4x: 24 pages of 16
        // tokens vs 6 slabs of 64).
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per_f32 = memory::kv_bytes_per_session(&paper, 20, 64);
        let gb = 6.0 * per_f32 / 1e9 + 1e-12;
        let slab = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                           KvPrecision::F32, gb, 512)
            .unwrap();
        assert_eq!(slab.capacity(), 6);
        let mut paged = KvCachePool::for_budget_layout(
            &host, a, &paper, 20, 64, KvPrecision::F32, gb, 512,
            KvLayout::Paged, 16,
        )
        .unwrap();
        assert_eq!(paged.pages_total(), 24,
                   "6 slabs x 64 tokens = 24 pages x 16 tokens");
        // short prompts (one page each): every page admits a session
        let short: Vec<i32> = (0..10).collect();
        let mut admitted = 0;
        while let Some(info) = paged.admit(&short, false) {
            // map the prompt's pages like prefill would
            paged.ensure_capacity(info.slot, short.len()).unwrap();
            admitted += 1;
            if admitted > 100 {
                break;
            }
        }
        assert!(
            admitted >= 2 * slab.capacity(),
            "paged admitted {admitted} short sessions vs slab {}",
            slab.capacity()
        );
        // and the modeled accounting stays within budget
        assert!(paged.modeled_peak_bytes() <= paged.modeled_budget_bytes());
    }

    #[test]
    fn prefix_reuse_shares_pages_and_cow_privatizes() {
        let mut p = paged_pool(3, 12, KvPrecision::F32);
        let a = p.slot(0).attn_dim;
        let prompt: Vec<i32> = (0..9).collect(); // 2 full pages + 1
        // session A computes and publishes
        let ia = p.admit(&prompt, true).unwrap();
        assert_eq!(ia.cached_tokens, 0);
        p.ensure_capacity(ia.slot, prompt.len()).unwrap();
        for t in 0..prompt.len() {
            for l in 0..2 {
                p.slot_mut(ia.slot)
                    .write(l, t, &vec![t as f32; a], &vec![t as f32; a]);
            }
        }
        p.slot_mut(ia.slot).advance_to(prompt.len());
        p.publish_prefix(ia.slot, &prompt);
        assert_eq!(p.prefix_index_len(), 2, "two full pages published");
        // session B shares the deepest full-page chain: 8 tokens
        let ib = p.admit(&prompt, true).unwrap();
        assert_eq!(ib.cached_tokens, 8);
        assert_eq!(p.paged_stats().prefix_hits, 1);
        assert_eq!(p.paged_stats().prefix_tokens_reused, 8);
        let a_ids: Vec<u32> =
            p.slot_page_refs(ia.slot).iter().map(|r| r.0).collect();
        let b_ids: Vec<u32> =
            p.slot_page_refs(ib.slot).iter().map(|r| r.0).collect();
        assert_eq!(&a_ids[..2], &b_ids[..2], "B maps A's pages");
        // B diverges: rolling back into the shared span and writing
        // must privatize, never touch A's copy
        p.slot_mut(ib.slot).advance_to(4);
        p.ensure_capacity(ib.slot, 6).unwrap();
        assert!(p.paged_stats().cow_copies >= 1);
        for l in 0..2 {
            p.slot_mut(ib.slot)
                .write(l, 5, &vec![99.0; a], &vec![99.0; a]);
        }
        assert_eq!(p.slot(ia.slot).k_at(0, 5), &vec![5.0; a][..],
                   "A's page must be untouched by B's divergence");
        assert_eq!(p.slot(ib.slot).k_at(0, 5), &vec![99.0; a][..]);
        let b_ids2: Vec<u32> =
            p.slot_page_refs(ib.slot).iter().map(|r| r.0).collect();
        assert_ne!(a_ids[1], b_ids2[1], "page 1 privatized");
    }

    #[test]
    fn paged_release_reclaims_only_unreferenced_pages() {
        let mut p = paged_pool(2, 8, KvPrecision::F32);
        let prompt: Vec<i32> = (0..8).collect();
        let ia = p.admit(&prompt, true).unwrap();
        p.ensure_capacity(ia.slot, 8).unwrap();
        p.slot_mut(ia.slot).advance_to(8);
        p.publish_prefix(ia.slot, &prompt);
        let used_before = p.pages_used();
        assert_eq!(used_before, 2);
        // release A: pages survive in the prefix index
        p.release(ia.slot);
        assert_eq!(p.pages_used(), 2, "prefix index retains the pages");
        assert_eq!(p.prefix_index_len(), 2);
        // clearing the index reclaims everything
        p.clear_prefix_index();
        assert_eq!(p.pages_used(), 0);
        assert_eq!(p.pages_free(), p.pages_total());
    }

    #[test]
    fn idle_prefix_stats_track_never_rehit_entries() {
        let mut p = paged_pool(4, 16, KvPrecision::F32);
        let prompt: Vec<i32> = (0..9).collect(); // 2 full pages + 1
        let ia = p.admit(&prompt, true).unwrap();
        p.ensure_capacity(ia.slot, 9).unwrap();
        p.slot_mut(ia.slot).advance_to(9);
        p.publish_prefix(ia.slot, &prompt);
        // freshly published, never re-hit: both entries are idle and
        // the pinned bytes equal two pages' host storage
        assert_eq!(p.prefix_idle_entries(), 2);
        let page_bytes = p.prefix_idle_bytes() / 2;
        assert!(page_bytes > 0);
        // a second session re-maps the chain: both entries got hit
        let ib = p.admit(&prompt, true).unwrap();
        assert_eq!(ib.cached_tokens, 8);
        assert_eq!(p.prefix_idle_entries(), 0);
        assert_eq!(p.prefix_idle_bytes(), 0);
        // a divergent publish adds fresh idle entries on top
        let other: Vec<i32> = (50..59).collect();
        let ic = p.admit(&other, true).unwrap();
        p.ensure_capacity(ic.slot, 9).unwrap();
        p.slot_mut(ic.slot).advance_to(9);
        p.publish_prefix(ic.slot, &other);
        assert_eq!(p.prefix_idle_entries(), 2);
        assert_eq!(p.prefix_idle_bytes(), 2 * page_bytes);
        // slab pools report zeros
        let slab = pool(2);
        assert_eq!(slab.prefix_idle_entries(), 0);
        assert_eq!(slab.prefix_idle_bytes(), 0);
    }

    #[test]
    fn admit_gates_on_page_availability() {
        // 1 slot's worth of pages: a prompt needing more pages than
        // exist is rejected up front; one fitting is admitted
        let mut p = paged_pool(4, 2, KvPrecision::F32);
        assert_eq!(p.session_token_capacity(), 8); // 2 pages x 4
        let long: Vec<i32> = (0..12).collect(); // needs 3 pages
        assert!(p.admit(&long, true).is_none());
        assert_eq!(p.in_use(), 0, "failed admit must roll back the slot");
        let ok: Vec<i32> = (0..7).collect();
        let i = p.admit(&ok, true).unwrap();
        p.ensure_capacity(i.slot, 7).unwrap();
        // both pages consumed: the next session cannot be admitted
        assert!(p.admit(&ok, true).is_none());
        p.release(i.slot);
        assert!(p.admit(&ok, true).is_some());
    }

    #[test]
    fn page_pressure_evicts_lru_prefixes() {
        let mut p = paged_pool(2, 2, KvPrecision::F32);
        let prompt: Vec<i32> = (100..108).collect();
        let i = p.admit(&prompt, true).unwrap();
        p.ensure_capacity(i.slot, 8).unwrap();
        p.slot_mut(i.slot).advance_to(8);
        p.publish_prefix(i.slot, &prompt);
        p.release(i.slot);
        assert_eq!(p.pages_free(), 0);
        assert_eq!(p.prefix_index_len(), 2);
        // a different prompt needs pages: the retained prefixes are
        // the only source and must be evicted LRU-first
        let other: Vec<i32> = (200..206).collect();
        let j = p.admit(&other, true).unwrap();
        assert_eq!(j.cached_tokens, 0);
        p.ensure_capacity(j.slot, 6).unwrap();
        assert_eq!(p.paged_stats().prefix_evictions, 2);
        assert_eq!(p.prefix_index_len(), 0);
    }

    #[test]
    fn slots_mut_many_rejects_aliasing_and_oob() {
        let mut p = pool(3);
        {
            let slots = p.slots_mut_many(&[2, 0]).unwrap();
            assert_eq!(slots.len(), 2);
        }
        assert!(p.slots_mut_many(&[0, 0]).is_err(), "aliased ids");
        assert!(p.slots_mut_many(&[3]).is_err(), "out of range");
        // disjoint mutation through the batch view sticks
        let a = p.slot(0).attn_dim;
        let row = vec![1.5f32; a];
        {
            let mut slots = p.slots_mut_many(&[1, 2]).unwrap();
            slots[0].write(0, 0, &row, &row);
            slots[1].write(0, 1, &row, &row);
        }
        assert_eq!(p.slot(1).k_at(0, 0), &row[..]);
        assert_eq!(p.slot(2).v_at(0, 1), &row[..]);
    }

    #[test]
    fn budget_sizing_matches_memory_accounting() {
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per = memory::kv_bytes_per_session(&paper, 20, 64);
        // budget for exactly 3 sessions
        let gb = 3.0 * per / 1e9 + 1e-12;
        let p = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                        KvPrecision::F32, gb, 64)
            .unwrap();
        assert_eq!(p.capacity(), 3);
        // capacity * per-session never exceeds the budget
        assert!(p.capacity() as f64 * per <= p.modeled_budget_bytes());
        // the scheduler-reachable cap wins when it is tighter
        let capped = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                             KvPrecision::F32, gb, 2)
            .unwrap();
        assert_eq!(capped.capacity(), 2);
        // zero-session budgets are a hard error
        assert!(KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                        KvPrecision::F32,
                                        per / 1e9 * 0.5, 64)
            .is_err());
        // paged: the page budget matches kv_page_bytes exactly
        let page = memory::kv_page_bytes(&paper, 20, 16, 4.0);
        let pp = KvCachePool::for_budget_layout(
            &host, a, &paper, 20, 64, KvPrecision::F32, gb, 64,
            KvLayout::Paged, 16,
        )
        .unwrap();
        assert_eq!(pp.pages_total(), (gb * 1e9 / page).floor() as usize);
        assert!(KvCachePool::for_budget_layout(
            &host, a, &paper, 20, 64, KvPrecision::F32,
            page / 1e9 * 0.5, 64, KvLayout::Paged, 16,
        )
        .is_err());
    }

    #[test]
    fn compact_mode_parses_and_labels() {
        assert_eq!(CompactMode::parse("off"), Some(CompactMode::Off));
        assert_eq!(CompactMode::parse("starve"),
                   Some(CompactMode::Starve));
        assert_eq!(CompactMode::parse("thresh=0.25"),
                   Some(CompactMode::Thresh(0.25)));
        assert_eq!(CompactMode::parse("thresh=0"),
                   Some(CompactMode::Thresh(0.0)));
        assert_eq!(CompactMode::parse("thresh=1"),
                   Some(CompactMode::Thresh(1.0)));
        for bad in ["", "on", "thresh", "thresh=", "thresh=1.5",
                    "thresh=-0.1", "thresh=NaN", "starve=1"] {
            assert_eq!(CompactMode::parse(bad), None, "accepted {bad}");
        }
        assert!(!CompactMode::Off.enabled());
        assert!(CompactMode::Starve.enabled());
        assert!(CompactMode::Thresh(0.5).enabled());
        assert_eq!(CompactMode::Off.label(), "off");
        assert_eq!(CompactMode::Starve.label(), "starve");
        assert_eq!(CompactMode::Thresh(0.25).label(), "thresh=0.25");
        // enabling any mode flips sub-page matching on; slab ignores
        let mut p = paged_pool(1, 4, KvPrecision::F32);
        assert_eq!(p.compact_mode(), CompactMode::Off);
        p.set_compact_mode(CompactMode::Starve);
        assert_eq!(p.compact_mode(), CompactMode::Starve);
        let slab = pool(1);
        assert_eq!(slab.compact_mode(), CompactMode::Off);
    }

    /// Seed one session with `prompt` into `p`: admit, map, write
    /// deterministic rows (k = t, v = -t), advance, publish. Returns
    /// the slot id.
    fn seed_session(p: &mut KvCachePool, prompt: &[i32]) -> usize {
        let a = p.slot(0).attn_dim;
        let info = p.admit(prompt, true).unwrap();
        p.ensure_capacity(info.slot, prompt.len()).unwrap();
        for t in info.cached_tokens..prompt.len() {
            for l in 0..2 {
                p.slot_mut(info.slot).write(
                    l, t, &vec![t as f32; a], &vec![-(t as f32); a]);
            }
        }
        p.slot_mut(info.slot).advance_to(prompt.len());
        p.publish_prefix(info.slot, prompt);
        info.slot
    }

    #[test]
    fn subpage_match_resumes_mid_page_bit_identically() {
        let mut p = paged_pool(3, 12, KvPrecision::F32);
        p.set_subpage_prefix(true);
        let a = p.slot(0).attn_dim;
        // A: 6 tokens = 1 full page + a 2-token tail; publishing adds
        // the full-page entry AND an index-owned copy of the tail span
        let pa: Vec<i32> = (0..6).collect();
        seed_session(&mut p, &pa);
        assert_eq!(p.prefix_index_len(), 2, "full page + sub-page tail");
        // B shares 6 tokens, diverges mid-page-1: full-page chain maps
        // page 0 (4 tokens), the sub-page scan extends it to 6
        let pb: Vec<i32> = vec![0, 1, 2, 3, 4, 5, 90, 91];
        let ib = p.admit(&pb, true).unwrap();
        assert_eq!(ib.cached_tokens, 6, "4 whole-page + 2 sub-page");
        let st = p.paged_stats();
        assert_eq!(st.prefix_hits, 1);
        assert_eq!(st.prefix_tokens_reused, 4, "whole pages only");
        assert_eq!(st.prefix_subpage_hits, 1);
        assert_eq!(st.prefix_subpage_tokens, 2);
        // the copied rows read back bit-identical to A's computation
        for t in 4..6 {
            assert_eq!(p.slot(ib.slot).k_at(0, t), &vec![t as f32; a][..]);
            assert_eq!(p.slot(ib.slot).v_at(1, t),
                       &vec![-(t as f32); a][..]);
        }
        // B's sub-span page is private: a write needs no CoW and can
        // never reach the index-owned original
        let cow_before = p.paged_stats().cow_copies;
        p.ensure_capacity(ib.slot, 7).unwrap();
        assert_eq!(p.paged_stats().cow_copies, cow_before);
        for l in 0..2 {
            p.slot_mut(ib.slot).write(l, 6, &vec![66.0; a],
                                      &vec![66.0; a]);
        }
        p.slot_mut(ib.slot).advance_to(7);
        // C shares only 2 tokens — below one page. The full-page chain
        // finds nothing; the sub-page scan still maps the verified span
        let pc: Vec<i32> = vec![0, 1, 77, 78];
        let ic = p.admit(&pc, true).unwrap();
        assert_eq!(ic.cached_tokens, 2, "sub-page reuse under one page");
        assert_eq!(p.paged_stats().prefix_subpage_hits, 2);
        assert_eq!(p.paged_stats().prefix_subpage_tokens, 4);
        for t in 0..2 {
            assert_eq!(p.slot(ic.slot).k_at(0, t), &vec![t as f32; a][..]);
        }
        // bytes-saved models whole-page + sub-page tokens uniformly
        let st = p.paged_stats();
        // modeled_bytes_per_session (1e6) spread over max_seq (16)
        let per_tok = 1e6 / 16.0;
        let want = (st.prefix_tokens_reused
            + st.prefix_subpage_tokens) as f64 * per_tok;
        assert!((p.prefix_bytes_saved_modeled() - want).abs() < 1e-6);
    }

    #[test]
    fn subpage_matching_stays_off_by_default() {
        let mut p = paged_pool(2, 12, KvPrecision::F32);
        let pa: Vec<i32> = (0..6).collect();
        seed_session(&mut p, &pa);
        assert_eq!(p.prefix_index_len(), 1, "no tail entry published");
        let pb: Vec<i32> = vec![0, 1, 2, 3, 4, 5, 90, 91];
        let ib = p.admit(&pb, true).unwrap();
        assert_eq!(ib.cached_tokens, 4, "whole pages only");
        assert_eq!(p.paged_stats().prefix_subpage_hits, 0);
    }

    #[test]
    fn frag_gauges_track_rewind_and_idle_index() {
        let mut p = paged_pool(2, 8, KvPrecision::F32);
        let a = p.slot(0).attn_dim;
        let i = p.admit(&[1, 2, 3], true).unwrap();
        p.ensure_capacity(i.slot, 11).unwrap(); // 3 pages
        for t in 0..11 {
            for l in 0..2 {
                p.slot_mut(i.slot).write(l, t, &vec![t as f32; a],
                                         &vec![t as f32; a]);
            }
        }
        p.slot_mut(i.slot).advance_to(11);
        // 11 live tokens: private partial tail strands 1 slot
        assert_eq!(p.frag_slots(), 1);
        assert_eq!(p.frag_pages(), 0);
        // rewind to 2: pages 1 and 2 are dead, tail slack is 2
        p.slot_mut(i.slot).rewind(2);
        assert_eq!(p.slot(i.slot).pages_mapped(), 3, "rewind keeps maps");
        assert_eq!(p.frag_slots(), 2);
        assert_eq!(p.frag_pages(), 2);
        let want = (2.0 + 2.0 / 4.0) / 8.0;
        assert!((p.frag_frac() - want).abs() < 1e-12);
        // a compaction pass reclaims exactly the dead pages
        let rep = p.compact(&[(i.slot, false)]);
        assert_eq!(rep.pages_reclaimed, 2);
        assert_eq!(rep.migrated, 0, "private tail needs no migration");
        assert!(rep.failed.is_empty());
        assert_eq!(p.slot(i.slot).pages_mapped(), 1);
        assert_eq!(p.frag_pages(), 0);
        assert_eq!(p.paged_stats().compactions, 1);
        assert_eq!(p.paged_stats().pages_reclaimed, 2);
        // re-extension after compaction faults fresh pages and works
        p.ensure_capacity(i.slot, 6).unwrap();
        for t in 2..6 {
            for l in 0..2 {
                p.slot_mut(i.slot).write(l, t, &vec![9.0; a],
                                         &vec![9.0; a]);
            }
        }
        p.slot_mut(i.slot).advance_to(6);
        // rows below the rewind point were never touched
        assert_eq!(p.slot(i.slot).k_at(0, 1), &vec![1.0; a][..]);
        // slab pools report zero everywhere
        let slab = pool(1);
        assert_eq!(slab.frag_slots(), 0);
        assert_eq!(slab.frag_pages(), 0);
        assert_eq!(slab.frag_frac(), 0.0);
    }

    #[test]
    fn compact_migrates_shared_tail_and_fault_aborts_cleanly() {
        let mut p = paged_pool(3, 12, KvPrecision::F32);
        let a = p.slot(0).attn_dim;
        // A computes 8 tokens (2 full pages, both published)
        let pa: Vec<i32> = (0..8).collect();
        let sa = seed_session(&mut p, &pa);
        // B maps both shared pages and extends to 10
        let pb: Vec<i32> = (0..10).collect();
        let ib = p.admit(&pb, true).unwrap();
        assert_eq!(ib.cached_tokens, 8);
        p.ensure_capacity(ib.slot, 10).unwrap();
        for t in 8..10 {
            for l in 0..2 {
                p.slot_mut(ib.slot).write(l, t, &vec![t as f32; a],
                                          &vec![t as f32; a]);
            }
        }
        p.slot_mut(ib.slot).advance_to(10);
        // B rolls back mid-page-1: its partial tail is A's page too
        p.slot_mut(ib.slot).rewind(6);
        let before = p.slot_page_refs(ib.slot);
        // injected fault: abort before any table change, report the id
        let rep = p.compact(&[(ib.slot, true)]);
        assert_eq!(rep.failed, vec![ib.slot]);
        assert_eq!(rep.migrated, 0);
        assert_eq!(p.slot_page_refs(ib.slot)[..2], before[..2],
                   "failed migration must not touch live pages");
        // clean pass: page 2 (dead) reclaimed, shared tail migrated
        let rep = p.compact(&[(ib.slot, false)]);
        assert_eq!(rep.migrated, 1);
        assert!(rep.failed.is_empty());
        assert_eq!(p.slot(ib.slot).pages_mapped(), 2);
        // B's tail is now private; A's copy was never written
        let refs = p.slot_page_refs(ib.slot);
        assert_eq!(refs[1].1, 1, "migrated tail page is private");
        assert_ne!(refs[1].0, p.slot_page_refs(sa)[1].0);
        for t in 4..6 {
            assert_eq!(p.slot(ib.slot).k_at(0, t),
                       &vec![t as f32; a][..], "migration is byte-exact");
            assert_eq!(p.slot(sa).k_at(0, t), &vec![t as f32; a][..]);
        }
        // B can diverge in place now — no CoW needed, A unaffected
        p.ensure_capacity(ib.slot, 7).unwrap();
        for l in 0..2 {
            p.slot_mut(ib.slot).write(l, 6, &vec![55.0; a],
                                      &vec![55.0; a]);
        }
        assert_eq!(p.slot(sa).k_at(0, 6), &vec![6.0; a][..]);
    }

    #[test]
    fn compact_stale_sweep_has_one_grace_window() {
        let mut p = paged_pool(2, 8, KvPrecision::F32);
        let pa: Vec<i32> = (0..8).collect();
        let sa = seed_session(&mut p, &pa);
        p.release(sa);
        assert_eq!(p.prefix_index_len(), 2);
        // first pass: freshly published entries survive (grace window)
        let rep = p.compact(&[]);
        assert_eq!(rep.pages_reclaimed, 0);
        assert_eq!(p.prefix_index_len(), 2);
        // untouched since: second pass sweeps them and frees the pages
        let rep = p.compact(&[]);
        assert_eq!(rep.pages_reclaimed, 2);
        assert_eq!(p.prefix_index_len(), 0);
        assert_eq!(p.pages_free(), p.pages_total());
        // a re-hit entry keeps resetting its window
        let sb = seed_session(&mut p, &pa);
        p.release(sb);
        p.compact(&[]); // grace
        // a longer prompt walks the whole chain: both entries re-hit
        let pa10: Vec<i32> = (0..10).collect();
        let ic = p.admit(&pa10, true).unwrap();
        assert_eq!(ic.cached_tokens, 8);
        p.release(ic.slot);
        let rep = p.compact(&[]);
        assert_eq!(rep.pages_reclaimed, 0, "recently-used entries stay");
        assert_eq!(p.prefix_index_len(), 2);
    }

    /// The churn acceptance criterion: an admit/finish mix with
    /// rewinds and sub-page shared prefixes, run twice at the same
    /// page budget. With compaction the pool reclaims >= 20% of its
    /// pages; with `--compact off` nothing is reclaimed; sub-page
    /// sharing (prefix shorter than one page) fires either way.
    fn churn(compact_on: bool) -> KvCachePool {
        let mut p = paged_pool(2, 16, KvPrecision::F32);
        p.set_subpage_prefix(true);
        if compact_on {
            p.set_compact_mode(CompactMode::Starve);
        }
        let a = p.slot(0).attn_dim;
        for round in 0..6i32 {
            let base = round * 1000;
            let mut live: Vec<usize> = Vec::new();
            for s in 0..2i32 {
                // 3 shared tokens (below one page), divergent after
                let mut prompt = vec![base, base + 1, base + 2];
                prompt.extend((0..3).map(|j| base + 10 + 20 * s + j));
                let Some(info) = p.admit(&prompt, true) else {
                    continue;
                };
                p.ensure_capacity(info.slot, prompt.len()).unwrap();
                for t in info.cached_tokens..prompt.len() {
                    for l in 0..2 {
                        p.slot_mut(info.slot).write(
                            l, t, &vec![t as f32; a],
                            &vec![-(t as f32); a]);
                    }
                }
                p.slot_mut(info.slot).advance_to(prompt.len());
                p.publish_prefix(info.slot, &prompt);
                // decode extends to a full 16 tokens...
                p.ensure_capacity(info.slot, 16).unwrap();
                for t in prompt.len()..16 {
                    for l in 0..2 {
                        p.slot_mut(info.slot).write(
                            l, t, &vec![t as f32; a],
                            &vec![-(t as f32); a]);
                    }
                }
                p.slot_mut(info.slot).advance_to(16);
                // ...then a speculative rollback strands the tail
                p.slot_mut(info.slot).rewind(2);
                live.push(info.slot);
            }
            if compact_on {
                let ids: Vec<(usize, bool)> =
                    live.iter().map(|&s| (s, false)).collect();
                p.compact(&ids);
            }
            for s in live {
                p.release(s);
            }
        }
        p
    }

    #[test]
    fn churn_compaction_reclaims_20pct_of_pages() {
        let on = churn(true);
        let off = churn(false);
        let total = on.pages_total() as u64;
        assert_eq!(off.pages_total() as u64, total, "equal budget");
        let reclaimed = on.paged_stats().pages_reclaimed;
        assert!(
            reclaimed * 5 >= total,
            "compaction reclaimed {reclaimed} of {total} pages (< 20%)"
        );
        assert_eq!(off.paged_stats().pages_reclaimed, 0);
        assert_eq!(off.paged_stats().compactions, 0);
        // sub-page prefixes (3 shared tokens < page_tokens 4) fired
        assert!(on.paged_stats().prefix_subpage_hits > 0);
        assert!(on.paged_stats().prefix_subpage_tokens > 0);
        // and the compacted pool ends the run less fragmented
        assert!(on.frag_frac() <= off.frag_frac());
        // both drain clean: full reclamation after the index clears
        for mut p in [on, off] {
            p.clear_prefix_index();
            assert_eq!(p.pages_used(), 0);
            assert_eq!(p.pages_free(), p.pages_total());
        }
    }

    #[test]
    fn budget_grows_with_quantization_headroom() {
        // nf4 leaves more device headroom than fp16 -> more sessions
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let dev = 8.0;
        let b4 = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Nf4), dev);
        let bf = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Fp16), dev);
        assert!(b4 > 0.0);
        let p4 = KvCachePool::for_budget(&host, a, &paper, 20, 256,
                                         KvPrecision::F32, b4,
                                         MAX_HOST_SLOTS)
            .unwrap();
        if bf > 0.0 {
            let pf = KvCachePool::for_budget(&host, a, &paper, 20, 256,
                                             KvPrecision::F32, bf,
                                             MAX_HOST_SLOTS)
                .unwrap();
            assert!(p4.capacity() >= pf.capacity());
        } else {
            assert!(p4.capacity() >= 1);
        }
    }
}
