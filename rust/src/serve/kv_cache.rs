//! Slab-allocated KV-cache pool for the serving subsystem, with a
//! selectable per-element precision.
//!
//! All session KV storage is preallocated up front as fixed-size slots
//! (one per concurrently-resident session), so the decode path never
//! allocates or frees *KV storage* and cannot exceed its memory budget
//! by construction (the engine's activation scratch lives in
//! `serve/workspace.rs` and is likewise reused across tokens).
//! Capacity derives from the precision-aware accounting in
//! `memory.rs`: the number of slots is what the modeled deployment
//! device could pin inside `serve_kv_budget_gb` (device headroom left
//! after the active `BitConfig`'s inference footprint), capped by
//! what the scheduler can actually keep resident (its batch cap plus
//! a stall allowance) and a hard host-side slab limit.
//!
//! Two KV representations ([`KvPrecision`], `--kv-bits` on the CLI):
//!
//! * **F32** — plain f32 rows (4 bytes/element), the exact numerics of
//!   the incremental decode reference path;
//! * **Int8** — signed int8 codes with per-[`quant::BLOCK`] f32 absmax
//!   scales, reusing the blockwise quantizer from `quant.rs` (the same
//!   scheme the paper applies to weights, extended to the KV cache the
//!   way QLoRA-style double quantization trades precision for serving
//!   memory). ~3.8x smaller than f32, so `for_budget` admits
//!   proportionally more concurrent sessions.

use crate::memory;
use crate::model::ModelConfig;
use crate::quant::{self, BLOCK};
use anyhow::{bail, ensure, Result};

/// Storage precision of the KV cache (`--kv-bits {32,8}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPrecision {
    /// f32 rows, bit-exact with the reference decode path.
    F32,
    /// int8 codes + per-block absmax scales (`quant::quantize_row_i8`).
    Int8,
}

impl KvPrecision {
    /// Map the CLI `--kv-bits` value onto a precision.
    pub fn from_bits(bits: u32) -> Option<KvPrecision> {
        match bits {
            32 => Some(KvPrecision::F32),
            8 => Some(KvPrecision::Int8),
            _ => None,
        }
    }

    pub fn bits(self) -> u32 {
        match self {
            KvPrecision::F32 => 32,
            KvPrecision::Int8 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::Int8 => "int8",
        }
    }

    /// Modeled deployment bytes per KV element, including the
    /// per-block f32 scale amortized over the block for Int8 (mirrors
    /// `QuantFormat::bits_per_param`). Feeds
    /// `memory::kv_bytes_per_session_at`.
    pub fn modeled_bytes_per_elem(self) -> f64 {
        match self {
            KvPrecision::F32 => 4.0,
            KvPrecision::Int8 => 1.0 + 4.0 / BLOCK as f64,
        }
    }
}

/// Backing storage of one slot, laid out `[L, max_seq, A]` contiguously
/// for both K and V.
#[derive(Debug)]
enum KvStore {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Int8 {
        k_codes: Vec<i8>,
        v_codes: Vec<i8>,
        /// per-(layer, position, block) absmax scales,
        /// `[L, max_seq, blocks_per_row]`
        k_scales: Vec<f32>,
        v_scales: Vec<f32>,
    },
}

/// Per-session KV storage: K and V stacks for every layer, position
/// and attention channel, at the pool's [`KvPrecision`].
#[derive(Debug)]
pub struct KvSlot {
    store: KvStore,
    /// tokens currently cached (positions `0..len` are valid)
    pub len: usize,
    n_layers: usize,
    max_seq: usize,
    attn_dim: usize,
    /// quantization blocks per KV row (Int8 only, 1-based even for F32
    /// so offsets stay uniform)
    blocks_per_row: usize,
}

impl KvSlot {
    fn new(n_layers: usize, max_seq: usize, attn_dim: usize,
           precision: KvPrecision) -> KvSlot {
        let n = n_layers * max_seq * attn_dim;
        let blocks_per_row = attn_dim.div_ceil(BLOCK);
        let store = match precision {
            KvPrecision::F32 => KvStore::F32 {
                k: vec![0.0; n],
                v: vec![0.0; n],
            },
            KvPrecision::Int8 => {
                let ns = n_layers * max_seq * blocks_per_row;
                KvStore::Int8 {
                    k_codes: vec![0; n],
                    v_codes: vec![0; n],
                    k_scales: vec![0.0; ns],
                    v_scales: vec![0.0; ns],
                }
            }
        };
        KvSlot {
            store,
            len: 0,
            n_layers,
            max_seq,
            attn_dim,
            blocks_per_row,
        }
    }

    pub fn precision(&self) -> KvPrecision {
        match self.store {
            KvStore::F32 { .. } => KvPrecision::F32,
            KvStore::Int8 { .. } => KvPrecision::Int8,
        }
    }

    #[inline]
    fn off(&self, layer: usize, t: usize) -> usize {
        debug_assert!(layer < self.n_layers && t < self.max_seq);
        (layer * self.max_seq + t) * self.attn_dim
    }

    #[inline]
    fn scale_off(&self, layer: usize, t: usize) -> usize {
        (layer * self.max_seq + t) * self.blocks_per_row
    }

    /// Write the K/V rows for position `t` of `layer` (quantizing when
    /// the slot is Int8). The caller advances `len` once per token via
    /// [`KvSlot::advance_to`].
    pub fn write(&mut self, layer: usize, t: usize, k_row: &[f32],
                 v_row: &[f32]) {
        assert!(t < self.max_seq, "kv overflow: pos {t} >= {}", self.max_seq);
        assert_eq!(k_row.len(), self.attn_dim);
        assert_eq!(v_row.len(), self.attn_dim);
        let o = self.off(layer, t);
        let so = self.scale_off(layer, t);
        let a = self.attn_dim;
        let nb = self.blocks_per_row;
        match &mut self.store {
            KvStore::F32 { k, v } => {
                k[o..o + a].copy_from_slice(k_row);
                v[o..o + a].copy_from_slice(v_row);
            }
            KvStore::Int8 { k_codes, v_codes, k_scales, v_scales } => {
                quant::quantize_row_i8(k_row, &mut k_codes[o..o + a],
                                       &mut k_scales[so..so + nb]);
                quant::quantize_row_i8(v_row, &mut v_codes[o..o + a],
                                       &mut v_scales[so..so + nb]);
            }
        }
    }

    pub fn advance_to(&mut self, len: usize) {
        debug_assert!(len <= self.max_seq);
        self.len = len;
    }

    /// K row at (layer, t) as f32: a direct slice for F32 slots, a
    /// dequantization into `scratch` for Int8 (scratch must hold at
    /// least `attn_dim` values). The returned slice borrows whichever
    /// storage backs it, so the engine's hot loop never copies on the
    /// f32 path and never allocates on either.
    pub fn k_row<'a>(&'a self, layer: usize, t: usize,
                     scratch: &'a mut [f32]) -> &'a [f32] {
        let o = self.off(layer, t);
        let a = self.attn_dim;
        match &self.store {
            KvStore::F32 { k, .. } => &k[o..o + a],
            KvStore::Int8 { k_codes, k_scales, .. } => {
                let so = self.scale_off(layer, t);
                quant::dequantize_row_i8(
                    &k_codes[o..o + a],
                    &k_scales[so..so + self.blocks_per_row],
                    &mut scratch[..a],
                );
                &scratch[..a]
            }
        }
    }

    /// V row at (layer, t); see [`KvSlot::k_row`].
    pub fn v_row<'a>(&'a self, layer: usize, t: usize,
                     scratch: &'a mut [f32]) -> &'a [f32] {
        let o = self.off(layer, t);
        let a = self.attn_dim;
        match &self.store {
            KvStore::F32 { v, .. } => &v[o..o + a],
            KvStore::Int8 { v_codes, v_scales, .. } => {
                let so = self.scale_off(layer, t);
                quant::dequantize_row_i8(
                    &v_codes[o..o + a],
                    &v_scales[so..so + self.blocks_per_row],
                    &mut scratch[..a],
                );
                &scratch[..a]
            }
        }
    }

    /// Borrow the raw f32 K row (F32 slots only — Int8 rows have no
    /// f32 representation to borrow; use [`KvSlot::k_row`]).
    #[inline]
    pub fn k_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        match &self.store {
            KvStore::F32 { k, .. } => &k[o..o + self.attn_dim],
            KvStore::Int8 { .. } => {
                panic!("k_at on an int8 slot; use k_row with scratch")
            }
        }
    }

    /// Borrow the raw f32 V row (F32 slots only); see [`KvSlot::k_at`].
    #[inline]
    pub fn v_at(&self, layer: usize, t: usize) -> &[f32] {
        let o = self.off(layer, t);
        match &self.store {
            KvStore::F32 { v, .. } => &v[o..o + self.attn_dim],
            KvStore::Int8 { .. } => {
                panic!("v_at on an int8 slot; use v_row with scratch")
            }
        }
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn attn_dim(&self) -> usize {
        self.attn_dim
    }

    fn reset(&mut self) {
        self.len = 0; // stale K/V rows are overwritten before reads
    }

    /// Host bytes of this slot's backing storage.
    pub fn host_bytes(&self) -> usize {
        match &self.store {
            KvStore::F32 { k, v } => {
                (k.len() + v.len()) * std::mem::size_of::<f32>()
            }
            KvStore::Int8 { k_codes, v_codes, k_scales, v_scales } => {
                k_codes.len() + v_codes.len()
                    + (k_scales.len() + v_scales.len())
                        * std::mem::size_of::<f32>()
            }
        }
    }
}

/// Fixed-capacity pool of [`KvSlot`]s with a free list.
pub struct KvCachePool {
    slots: Vec<KvSlot>,
    free: Vec<usize>,
    precision: KvPrecision,
    /// reusable aliasing bitmap for `slots_mut_many` (cleared per
    /// call; kept here so the batched decode step allocates nothing
    /// for the check)
    seen: Vec<bool>,
    /// modeled deployment bytes one session pins (paper arch, at the
    /// pool's KV precision)
    modeled_bytes_per_session: f64,
    /// modeled deployment budget in bytes
    modeled_budget_bytes: f64,
    peak_in_use: usize,
}

/// Hard host-side cap on preallocated slots, independent of how large
/// the modeled device headroom is (keeps the simulator's RSS bounded).
pub const MAX_HOST_SLOTS: usize = 1024;

impl KvCachePool {
    /// Size the pool from the modeled deployment: `budget_gb` of KV
    /// headroom on the target device (see `memory::serve_kv_budget_gb`)
    /// divided by the per-session KV bytes of the paper-scale
    /// architecture at this pruning rate *and KV precision* — int8 KV
    /// packs ~3.8x more sessions into the same budget. Host slots are
    /// shaped by the *served* (simulator) model config and capped at
    /// `host_slot_cap` — the scheduler's reachable concurrency — so a
    /// huge modeled headroom doesn't preallocate megabytes of slab no
    /// session can ever touch.
    #[allow(clippy::too_many_arguments)]
    pub fn for_budget(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        paper_cfg: &ModelConfig,
        rate_pct: u32,
        max_seq: usize,
        precision: KvPrecision,
        budget_gb: f64,
        host_slot_cap: usize,
    ) -> Result<KvCachePool> {
        let per_session = memory::kv_bytes_per_session_at(
            paper_cfg,
            rate_pct,
            max_seq,
            precision.modeled_bytes_per_elem(),
        );
        let budget_bytes = budget_gb * 1e9;
        let n = (budget_bytes / per_session).floor() as usize;
        if n == 0 {
            bail!(
                "KV budget {budget_gb:.3} GB holds zero sessions \
                 ({:.1} MB each at max_seq {max_seq}, {} KV) — raise \
                 --kv-budget-gb, lower --max-seq, or drop --kv-bits",
                per_session / 1e6,
                precision.label()
            );
        }
        Ok(Self::with_slots(
            host_cfg,
            host_attn_dim,
            n.min(MAX_HOST_SLOTS).min(host_slot_cap.max(1)),
            max_seq,
            precision,
            per_session,
            budget_bytes,
        ))
    }

    /// Direct construction with an explicit slot count (tests).
    pub fn with_slots(
        host_cfg: &ModelConfig,
        host_attn_dim: usize,
        n_slots: usize,
        max_seq: usize,
        precision: KvPrecision,
        modeled_bytes_per_session: f64,
        modeled_budget_bytes: f64,
    ) -> KvCachePool {
        assert!(n_slots > 0);
        let slots = (0..n_slots)
            .map(|_| {
                KvSlot::new(host_cfg.n_layers, max_seq, host_attn_dim,
                            precision)
            })
            .collect();
        KvCachePool {
            slots,
            free: (0..n_slots).rev().collect(),
            precision,
            seen: vec![false; n_slots],
            modeled_bytes_per_session,
            modeled_budget_bytes,
            peak_in_use: 0,
        }
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Modeled deployment bytes currently pinned / at peak.
    pub fn modeled_peak_bytes(&self) -> f64 {
        self.peak_in_use as f64 * self.modeled_bytes_per_session
    }

    pub fn modeled_budget_bytes(&self) -> f64 {
        self.modeled_budget_bytes
    }

    /// Host bytes of the whole preallocated slab.
    pub fn host_slab_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.host_bytes()).sum()
    }

    /// Claim a free slot; `None` when the budget is exhausted (callers
    /// queue or reject — see `admission.rs`).
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        self.slots[id].reset();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(id)
    }

    /// Return a slot to the free list.
    pub fn release(&mut self, id: usize) {
        debug_assert!(!self.free.contains(&id), "double release of {id}");
        self.slots[id].reset();
        self.free.push(id);
    }

    pub fn slot(&self, id: usize) -> &KvSlot {
        &self.slots[id]
    }

    pub fn slot_mut(&mut self, id: usize) -> &mut KvSlot {
        &mut self.slots[id]
    }

    /// Mutably borrow several distinct slots at once — the batched
    /// decode step (`engine::Engine::step_batch`) updates every active
    /// session's cache within one fused pass. Errors if any id is out
    /// of range or repeated (repetition would alias `&mut`s). The
    /// returned `Vec` of borrows is the one per-step allocation on the
    /// decode hot path (a reusable buffer of references is not
    /// expressible — its lifetime changes per call); the aliasing
    /// bitmap is pool-owned scratch.
    pub fn slots_mut_many<'a>(&'a mut self, ids: &[usize])
                              -> Result<Vec<&'a mut KvSlot>> {
        let n = self.slots.len();
        self.seen.fill(false);
        for &id in ids {
            ensure!(id < n, "slot {id} out of range ({n} slots)");
            ensure!(!self.seen[id],
                    "slot {id} requested twice in one batch");
            self.seen[id] = true;
        }
        // validation complete: from here on, nothing touches `self`
        // except through the raw pointer below
        let base = self.slots.as_mut_ptr();
        let mut out: Vec<&'a mut KvSlot> = Vec::with_capacity(ids.len());
        for &id in ids {
            // SAFETY: `id < n` keeps the pointer in-bounds of the
            // `slots` allocation, and the `seen` pass above guarantees
            // ids are pairwise distinct, so each `&mut` refers to a
            // different element and none alias. No other access to
            // `self` interleaves while these borrows exist, and they
            // all carry lifetime 'a tied to `&'a mut self`, so the Vec
            // cannot outlive (or race with) the pool borrow.
            out.push(unsafe { &mut *base.add(id) });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BitConfig, QuantFormat};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn pool_p(n: usize, precision: KvPrecision) -> KvCachePool {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let a = cfg.pruned(0).attn_dim(&cfg);
        KvCachePool::with_slots(&cfg, a, n, 16, precision, 1e6,
                                n as f64 * 1e6)
    }

    fn pool(n: usize) -> KvCachePool {
        pool_p(n, KvPrecision::F32)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        assert!(p.alloc().is_none(), "over-allocation must fail");
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "free list reuses the released slot");
        assert_eq!(p.peak_in_use(), 2);
    }

    #[test]
    fn released_slot_is_reset() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let (k, v) = (vec![1.0; a], vec![2.0; a]);
        p.slot_mut(id).write(0, 0, &k, &v);
        p.slot_mut(id).advance_to(1);
        assert_eq!(p.slot(id).len, 1);
        p.release(id);
        let id2 = p.alloc().unwrap();
        assert_eq!(p.slot(id2).len, 0);
    }

    #[test]
    fn slot_rows_roundtrip() {
        let mut p = pool(1);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let k: Vec<f32> = (0..a).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..a).map(|i| -(i as f32)).collect();
        p.slot_mut(id).write(1, 3, &k, &v);
        assert_eq!(p.slot(id).k_at(1, 3), &k[..]);
        assert_eq!(p.slot(id).v_at(1, 3), &v[..]);
        // other positions untouched
        assert!(p.slot(id).k_at(1, 2).iter().all(|&x| x == 0.0));
        // the precision-generic accessors agree with the raw slices
        let mut scratch = vec![0.0f32; a];
        assert_eq!(p.slot(id).k_row(1, 3, &mut scratch), &k[..]);
        assert_eq!(p.slot(id).v_row(1, 3, &mut scratch), &v[..]);
    }

    #[test]
    fn int8_roundtrip_within_quant_bound() {
        // property sweep: random K/V rows must come back within the
        // analytic bound `quant::roundtrip_error_bound` predicts for
        // blockwise int8 absmax quantization
        let mut p = pool_p(1, KvPrecision::Int8);
        let id = p.alloc().unwrap();
        let a = p.slot(id).attn_dim;
        let mut rng = Rng::new(321);
        let mut scratch = vec![0.0f32; a];
        for trial in 0..40 {
            let layer = rng.below(2);
            let t = rng.below(16);
            let scale = rng.uniform_in(0.01, 8.0);
            let k = Tensor::randn(&[1, a], scale, &mut rng);
            let v = Tensor::randn(&[1, a], scale, &mut rng);
            p.slot_mut(id).write(layer, t, k.row(0), v.row(0));
            let bk = quant::roundtrip_error_bound(&k, QuantFormat::Int8);
            let bv = quant::roundtrip_error_bound(&v, QuantFormat::Int8);
            let kr = p.slot(id).k_row(layer, t, &mut scratch).to_vec();
            for (x, y) in k.row(0).iter().zip(&kr) {
                assert!((x - y).abs() <= bk,
                        "trial {trial}: k err {} > {bk}", (x - y).abs());
            }
            let vr = p.slot(id).v_row(layer, t, &mut scratch).to_vec();
            for (x, y) in v.row(0).iter().zip(&vr) {
                assert!((x - y).abs() <= bv,
                        "trial {trial}: v err {} > {bv}", (x - y).abs());
            }
        }
    }

    #[test]
    fn int8_slab_at_least_3p5x_smaller_than_f32() {
        let pf = pool_p(4, KvPrecision::F32);
        let pi = pool_p(4, KvPrecision::Int8);
        assert_eq!(pf.capacity(), pi.capacity());
        let ratio =
            pf.host_slab_bytes() as f64 / pi.host_slab_bytes() as f64;
        assert!(ratio >= 3.5, "int8 KV slab only {ratio:.2}x smaller");
        // per-slot view agrees
        let rs = pf.slot(0).host_bytes() as f64
            / pi.slot(0).host_bytes() as f64;
        assert!(rs >= 3.5, "per-slot ratio {rs:.2}");
    }

    #[test]
    fn int8_budget_admits_at_least_2x_sessions() {
        // the --kv-bits acceptance criterion: same modeled budget,
        // >= 2x the concurrent sessions at int8 (the analytic ratio is
        // ~3.76x; MAX_HOST_SLOTS and the slot cap must not mask it)
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per_f32 = memory::kv_bytes_per_session(&paper, 20, 64);
        let gb = 6.0 * per_f32 / 1e9 + 1e-12;
        let pf = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                         KvPrecision::F32, gb, 512)
            .unwrap();
        let pi = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                         KvPrecision::Int8, gb, 512)
            .unwrap();
        assert_eq!(pf.capacity(), 6);
        assert!(
            pi.capacity() >= 2 * pf.capacity(),
            "int8 admitted {} vs f32 {}",
            pi.capacity(),
            pf.capacity()
        );
    }

    #[test]
    fn slots_mut_many_rejects_aliasing_and_oob() {
        let mut p = pool(3);
        {
            let slots = p.slots_mut_many(&[2, 0]).unwrap();
            assert_eq!(slots.len(), 2);
        }
        assert!(p.slots_mut_many(&[0, 0]).is_err(), "aliased ids");
        assert!(p.slots_mut_many(&[3]).is_err(), "out of range");
        // disjoint mutation through the batch view sticks
        let a = p.slot(0).attn_dim;
        let row = vec![1.5f32; a];
        {
            let mut slots = p.slots_mut_many(&[1, 2]).unwrap();
            slots[0].write(0, 0, &row, &row);
            slots[1].write(0, 1, &row, &row);
        }
        assert_eq!(p.slot(1).k_at(0, 0), &row[..]);
        assert_eq!(p.slot(2).v_at(0, 1), &row[..]);
    }

    #[test]
    fn budget_sizing_matches_memory_accounting() {
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let per = memory::kv_bytes_per_session(&paper, 20, 64);
        // budget for exactly 3 sessions
        let gb = 3.0 * per / 1e9 + 1e-12;
        let p = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                        KvPrecision::F32, gb, 64)
            .unwrap();
        assert_eq!(p.capacity(), 3);
        // capacity * per-session never exceeds the budget
        assert!(p.capacity() as f64 * per <= p.modeled_budget_bytes());
        // the scheduler-reachable cap wins when it is tighter
        let capped = KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                             KvPrecision::F32, gb, 2)
            .unwrap();
        assert_eq!(capped.capacity(), 2);
        // zero-session budgets are a hard error
        assert!(KvCachePool::for_budget(&host, a, &paper, 20, 64,
                                        KvPrecision::F32,
                                        per / 1e9 * 0.5, 64)
            .is_err());
    }

    #[test]
    fn budget_grows_with_quantization_headroom() {
        // nf4 leaves more device headroom than fp16 -> more sessions
        let host = ModelConfig::preset("tiny").unwrap();
        let a = host.pruned(0).attn_dim(&host);
        let paper = ModelConfig::paper_7b();
        let dev = 8.0;
        let b4 = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Nf4), dev);
        let bf = memory::serve_kv_budget_gb(
            &paper, 20,
            &BitConfig::uniform(paper.n_layers, QuantFormat::Fp16), dev);
        assert!(b4 > 0.0);
        let p4 = KvCachePool::for_budget(&host, a, &paper, 20, 256,
                                         KvPrecision::F32, b4,
                                         MAX_HOST_SLOTS)
            .unwrap();
        if bf > 0.0 {
            let pf = KvCachePool::for_budget(&host, a, &paper, 20, 256,
                                             KvPrecision::F32, bf,
                                             MAX_HOST_SLOTS)
                .unwrap();
            assert!(p4.capacity() >= pf.capacity());
        } else {
            assert!(p4.capacity() >= 1);
        }
    }
}
