//! LoRA adapters + initialization strategies (paper §3.3, Table 2).
//!
//! Adapter convention (matches the L2 model ABI):
//!   y = x W^T + (x A^T) B^T * (alpha / r),  A [r, in], B [out, r]
//! i.e. the effective weight is  W_eff = W + s * (B @ A).
//!
//! Init strategies:
//!  * Gaussian — A ~ N(0, 0.02^2), B = 0 (classic LoRA);
//!  * LoftQ    — alternate  Q = quant(W - s BA)  /  (B, A) = SVD_r(W - Q)/s
//!    so the *quantized* base plus adapter approximates the original
//!    full-precision W (Eq. 10); `iters` controls the alternation count
//!    (Table 2 ablates 1/2/4);
//!  * PiSSA    — principal singular directions of W go into the adapter,
//!    the base keeps the residual (Meng, 2024).

use crate::linalg;
use crate::model::{ParamStore, PROJS};
use crate::quant::{simulate, BitConfig, QuantFormat};
use crate::rng::Rng;
use crate::tensor::Tensor;
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMethod {
    Gaussian,
    LoftQ { iters: usize },
    Pissa,
}

impl InitMethod {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gaussian" => Some(InitMethod::Gaussian),
            "pissa" => Some(InitMethod::Pissa),
            _ => s.strip_prefix("loftq").map(|suffix| InitMethod::LoftQ {
                iters: suffix.parse().unwrap_or(1),
            }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            InitMethod::Gaussian => "gaussian".into(),
            InitMethod::LoftQ { iters } => format!("loftq{iters}"),
            InitMethod::Pissa => "pissa".into(),
        }
    }
}

/// Stacked adapters for the whole model: 14 tensors in ABI order
/// (A_wq, B_wq, A_wk, B_wk, ... matching configs.PROJS).
#[derive(Clone, Debug)]
pub struct LoraState {
    pub tensors: Vec<Tensor>,
    pub rank: usize,
    pub alpha: usize,
}

impl LoraState {
    pub fn scaling(&self) -> f32 {
        self.alpha as f32 / self.rank as f32
    }

    pub fn shapes(store: &ParamStore) -> Vec<Vec<usize>> {
        let cfg = &store.cfg;
        let r = cfg.lora_rank;
        let mut out = Vec::new();
        for p in PROJS {
            let (o, i) = cfg.proj_shape(&store.ps, p);
            out.push(vec![cfg.n_layers, r, i]);
            out.push(vec![cfg.n_layers, o, r]);
        }
        out
    }

    pub fn zeros(store: &ParamStore) -> LoraState {
        let tensors =
            Self::shapes(store).iter().map(|s| Tensor::zeros(s)).collect();
        LoraState {
            tensors,
            rank: store.cfg.lora_rank,
            alpha: store.cfg.lora_alpha,
        }
    }

    pub fn trainable_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// (A, B) slabs for one layer/projection as fresh tensors.
    pub fn layer_ab(&self, proj_idx: usize, layer: usize) -> (Tensor, Tensor) {
        let a_stack = &self.tensors[2 * proj_idx];
        let b_stack = &self.tensors[2 * proj_idx + 1];
        let (ash, ad) = a_stack.slab(layer);
        let (bsh, bd) = b_stack.slab(layer);
        (Tensor::new(ash, ad.to_vec()), Tensor::new(bsh, bd.to_vec()))
    }

    fn set_layer_ab(&mut self, proj_idx: usize, layer: usize, a: &Tensor,
                    b: &Tensor) {
        self.tensors[2 * proj_idx].slab_mut(layer).copy_from_slice(a.data());
        self.tensors[2 * proj_idx + 1]
            .slab_mut(layer)
            .copy_from_slice(b.data());
    }
}

/// Result of preparing a (possibly quantized) fine-tuning base.
pub struct PreparedModel {
    /// frozen base weights (dequantized-simulated where quantized)
    pub base: ParamStore,
    /// adapter initialization
    pub lora: LoraState,
}

/// Gaussian LoRA init over an fp16 or simulated-quantized base.
pub fn init_gaussian(store: &ParamStore, bits: &BitConfig, rng: &mut Rng)
                     -> PreparedModel {
    let base = quantize_base(store, bits);
    let mut lora = LoraState::zeros(store);
    // A ~ N(0, 0.02), B = 0
    for (i, t) in lora.tensors.iter_mut().enumerate() {
        if i % 2 == 0 {
            rng.fill_normal(t.data_mut(), 0.02);
        }
    }
    PreparedModel { base, lora }
}

/// Simulated-quantize every projection of `store` per the per-layer
/// bit config (norms/embeddings stay fp32 as in QLoRA).
pub fn quantize_base(store: &ParamStore, bits: &BitConfig) -> ParamStore {
    assert_eq!(bits.n_layers(), store.cfg.n_layers);
    let mut base = store.clone();
    for (pi, proj) in PROJS.iter().enumerate() {
        let _ = pi;
        for l in 0..store.cfg.n_layers {
            let fmt = bits.layers[l];
            if fmt == QuantFormat::Fp16 {
                continue;
            }
            let w = store.layer_proj(l, proj);
            base.set_layer_proj(l, proj, &simulate(&w, fmt));
        }
    }
    base
}

/// LoftQ: alternately quantize the residual and refit the low-rank
/// correction so that  quant(W - sBA) + sBA ~ W  (Eq. 10).
pub fn init_loftq(store: &ParamStore, bits: &BitConfig, iters: usize,
                  rng: &mut Rng) -> Result<PreparedModel> {
    let cfg = &store.cfg;
    let s = cfg.lora_alpha as f32 / cfg.lora_rank as f32;
    let r = cfg.lora_rank;
    let mut base = store.clone();
    let mut lora = LoraState::zeros(store);

    for (pi, proj) in PROJS.iter().enumerate() {
        for l in 0..cfg.n_layers {
            let fmt = bits.layers[l];
            let w = store.layer_proj(l, proj);
            if fmt == QuantFormat::Fp16 {
                // nothing to correct; plain zero-init adapter
                base.set_layer_proj(l, proj, &w);
                continue;
            }
            let mut a = Tensor::zeros(&[r, w.shape()[1]]);
            let mut b = Tensor::zeros(&[w.shape()[0], r]);
            let mut q = simulate(&w, fmt);
            for _ in 0..iters {
                // residual the adapter must absorb
                let resid = w.sub(&q);
                let svd = linalg::randomized_svd(&resid, r, 8, 1, rng);
                // B = U * S / s ; A = V^T  (any split works; keep A orthonormal)
                let mut us = svd.u.clone();
                for i in 0..us.shape()[0] {
                    for kk in 0..r {
                        let v = us.at2(i, kk) * svd.s[kk] / s;
                        us.data_mut()[i * r + kk] = v;
                    }
                }
                b = us;
                a = svd.v.transpose2();
                // re-quantize what the adapter does not cover
                let ba = linalg::matmul(&b, &a).scale(s);
                q = simulate(&w.sub(&ba), fmt);
            }
            base.set_layer_proj(l, proj, &q);
            lora.set_layer_ab(pi, l, &a, &b);
        }
    }
    Ok(PreparedModel { base, lora })
}

/// PiSSA: adapter = principal rank-r part of W, base = residual (then
/// simulated-quantized per the bit config).
pub fn init_pissa(store: &ParamStore, bits: &BitConfig, rng: &mut Rng)
                  -> Result<PreparedModel> {
    let cfg = &store.cfg;
    let s = cfg.lora_alpha as f32 / cfg.lora_rank as f32;
    let r = cfg.lora_rank;
    let mut base = store.clone();
    let mut lora = LoraState::zeros(store);

    for (pi, proj) in PROJS.iter().enumerate() {
        for l in 0..cfg.n_layers {
            let fmt = bits.layers[l];
            let w = store.layer_proj(l, proj);
            let svd = linalg::randomized_svd(&w, r, 8, 1, rng);
            let mut us = svd.u.clone();
            for i in 0..us.shape()[0] {
                for kk in 0..r {
                    let v = us.at2(i, kk) * svd.s[kk] / s;
                    us.data_mut()[i * r + kk] = v;
                }
            }
            let b = us;
            let a = svd.v.transpose2();
            let ba = linalg::matmul(&b, &a).scale(s);
            let resid = w.sub(&ba);
            let q = if fmt == QuantFormat::Fp16 {
                resid
            } else {
                simulate(&resid, fmt)
            };
            base.set_layer_proj(l, proj, &q);
            lora.set_layer_ab(pi, l, &a, &b);
        }
    }
    Ok(PreparedModel { base, lora })
}

/// Dispatch on the init method.
pub fn prepare(store: &ParamStore, bits: &BitConfig, method: InitMethod,
               rng: &mut Rng) -> Result<PreparedModel> {
    match method {
        InitMethod::Gaussian => Ok(init_gaussian(store, bits, rng)),
        InitMethod::LoftQ { iters } => init_loftq(store, bits, iters, rng),
        InitMethod::Pissa => init_pissa(store, bits, rng),
    }
}

/// || W - (Q + s BA) ||_F summed over all projections — the LoftQ
/// objective value (diagnostic + tests).
pub fn reconstruction_error(orig: &ParamStore, prep: &PreparedModel) -> f64 {
    let s = prep.lora.scaling();
    let mut total = 0.0f64;
    for (pi, proj) in PROJS.iter().enumerate() {
        for l in 0..orig.cfg.n_layers {
            let w = orig.layer_proj(l, proj);
            let q = prep.base.layer_proj(l, proj);
            let (a, b) = prep.lora.layer_ab(pi, l);
            let ba = linalg::matmul(&b, &a).scale(s);
            let mut qba = q.clone();
            qba.add_assign(&ba);
            total += w.sub(&qba).frobenius_norm() as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn setup() -> (ParamStore, BitConfig) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 5);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        (store, bits)
    }

    #[test]
    fn gaussian_init_b_zero_a_nonzero() {
        let (store, bits) = setup();
        let mut rng = Rng::new(1);
        let p = init_gaussian(&store, &bits, &mut rng);
        for (i, t) in p.lora.tensors.iter().enumerate() {
            if i % 2 == 0 {
                assert!(t.max_abs() > 0.0, "A stack {i} all zero");
            } else {
                assert_eq!(t.max_abs(), 0.0, "B stack {i} not zero");
            }
        }
    }

    #[test]
    fn quantize_base_changes_projections_not_norms() {
        let (store, bits) = setup();
        let base = quantize_base(&store, &bits);
        assert_ne!(
            base.weights[crate::model::proj_index("wq")].data(),
            store.weights[crate::model::proj_index("wq")].data()
        );
        assert_eq!(base.weights[1].data(), store.weights[1].data());
        assert_eq!(base.weights[0].data(), store.weights[0].data());
    }

    #[test]
    fn loftq_reduces_reconstruction_error_vs_plain_quant() {
        let (store, bits) = setup();
        let mut rng = Rng::new(2);
        let plain = PreparedModel {
            base: quantize_base(&store, &bits),
            lora: LoraState::zeros(&store),
        };
        let e_plain = reconstruction_error(&store, &plain);
        let loftq = init_loftq(&store, &bits, 1, &mut rng).unwrap();
        let e_loftq = reconstruction_error(&store, &loftq);
        assert!(
            e_loftq < e_plain * 0.95,
            "loftq {e_loftq} !< plain {e_plain}"
        );
    }

    #[test]
    fn loftq_more_iters_not_worse() {
        let (store, bits) = setup();
        let mut rng = Rng::new(3);
        let e1 = reconstruction_error(
            &store, &init_loftq(&store, &bits, 1, &mut rng).unwrap());
        let mut rng = Rng::new(3);
        let e4 = reconstruction_error(
            &store, &init_loftq(&store, &bits, 4, &mut rng).unwrap());
        assert!(e4 <= e1 * 1.05, "iters=4 {e4} much worse than iters=1 {e1}");
    }

    #[test]
    fn loftq_fp16_layers_passthrough() {
        let (store, mut bits) = setup();
        bits.layers[0] = QuantFormat::Fp16;
        let mut rng = Rng::new(4);
        let p = init_loftq(&store, &bits, 1, &mut rng).unwrap();
        assert_eq!(
            p.base.layer_proj(0, "wq").data(),
            store.layer_proj(0, "wq").data()
        );
        let (a, _b) = p.lora.layer_ab(0, 0);
        assert_eq!(a.max_abs(), 0.0);
    }

    #[test]
    fn pissa_adapter_captures_principal_energy() {
        let (store, bits) = setup();
        let mut rng = Rng::new(5);
        let p = init_pissa(&store, &bits, &mut rng).unwrap();
        // adapter should be distinctly non-zero on both A and B
        let (a, b) = p.lora.layer_ab(0, 0);
        assert!(a.max_abs() > 0.0 && b.max_abs() > 0.0);
        // reconstruction with adapter should beat plain quantization
        let plain = PreparedModel {
            base: quantize_base(&store, &bits),
            lora: LoraState::zeros(&store),
        };
        let e_pissa = reconstruction_error(&store, &p);
        let e_plain = reconstruction_error(&store, &plain);
        assert!(e_pissa < e_plain * 1.5);
    }

    #[test]
    fn trainable_params_much_smaller_than_model() {
        let (store, _) = setup();
        let lora = LoraState::zeros(&store);
        assert!(lora.trainable_params() * 5 < store.total_params());
    }

    #[test]
    fn mixed_bits_apply_per_layer() {
        let (store, mut bits) = setup();
        bits.layers[1] = QuantFormat::Int8;
        let base = quantize_base(&store, &bits);
        // layer 1 int8 should be closer to original than layer 0 nf4
        let e0 = store
            .layer_proj(0, "w_up")
            .sub(&base.layer_proj(0, "w_up"))
            .frobenius_norm();
        let e1 = store
            .layer_proj(1, "w_up")
            .sub(&base.layer_proj(1, "w_up"))
            .frobenius_norm();
        assert!(e1 < e0, "int8 err {e1} !< nf4 err {e0}");
    }

    #[test]
    fn parse_labels_roundtrip() {
        for m in [InitMethod::Gaussian, InitMethod::LoftQ { iters: 2 },
                  InitMethod::Pissa] {
            assert_eq!(InitMethod::parse(&m.label()), Some(m));
        }
    }
}
