//! Synthetic data substrate.
//!
//! The paper fine-tunes on Alpaca-50k and evaluates zero-shot on seven
//! commonsense multiple-choice suites. Neither is available offline, so
//! we build the closest synthetic equivalents (DESIGN.md §3):
//!
//!  * **corpus** — a Zipf-Markov language over the model vocabulary:
//!    each token has a few preferred successors (learnable structure)
//!    plus a Zipfian background (noise floor). Pretraining/fine-tuning
//!    streams are sampled from it.
//!  * **tasks** — seven multiple-choice suites with distinct formats
//!    (choice counts, context/choice lengths, distractor difficulty)
//!    standing in for BoolQ/PIQA/HellaSwag/WinoGrande/ARC-e/ARC-c/OBQA.
//!    The correct choice is the language's true continuation; the
//!    distractors are perturbed or off-chain sequences. Scoring is
//!    length-normalized choice log-likelihood, exactly the
//!    lm-eval-harness contract the paper uses.

use crate::rng::Rng;

pub const TOK_PAD: i32 = 0;
pub const TOK_BOS: i32 = 1;
pub const TOK_SEP: i32 = 2;
const RESERVED: usize = 3;

/// Number of preferred successors per token.
const FANOUT: usize = 4;
/// Probability mass on the preferred successors (rest is Zipf noise).
const CHAIN_MASS: f64 = 0.85;
const SUCC_W: [f64; FANOUT] = [0.5, 0.25, 0.15, 0.10];

/// A deterministic synthetic language over `vocab` tokens.
///
/// Transitions are **second-order**: the preferred-successor set is a
/// deterministic hash of the (previous, current) token pair. A model
/// must therefore learn pair-conditioned structure — a capacity-bound
/// task at our model sizes, which is exactly what makes structured
/// pruning and per-layer precision *matter* (a first-order chain was
/// trivially saturated by every configuration; see DESIGN.md §3).
#[derive(Clone)]
pub struct Language {
    pub vocab: usize,
    /// hash salt for the pair -> successor-set map
    salt: u64,
    /// Zipf background cumulative weights
    zipf_cum: Vec<f64>,
    pub style_seed: u64,
}

impl Language {
    /// `style_seed` selects a dialect: the base-corpus model and the
    /// "chat" (Vicuna stand-in) model use different seeds.
    pub fn new(vocab: usize, style_seed: u64) -> Language {
        assert!(vocab > RESERVED + FANOUT);
        // Zipf background over the non-reserved vocab
        let mut cum = Vec::with_capacity(vocab - RESERVED);
        let mut total = 0.0;
        for i in 0..vocab - RESERVED {
            total += 1.0 / (i + 1) as f64;
            cum.push(total);
        }
        Language {
            vocab,
            salt: style_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ 0xC0FF_EE15_BADC_0DE5,
            zipf_cum: cum,
            style_seed,
        }
    }

    fn zipf(&self, rng: &mut Rng) -> i32 {
        let total = *self.zipf_cum.last().unwrap();
        let u = rng.uniform() * total;
        let idx = self.zipf_cum.partition_point(|&c| c < u);
        (RESERVED + idx.min(self.zipf_cum.len() - 1)) as i32
    }

    /// Number of context clusters: the hidden state a model must carry
    /// from the previous token. Small enough that the
    /// (cluster, current) table is learnable at our model sizes, large
    /// enough that ignoring `prev` costs real likelihood.
    pub const N_CLUSTERS: usize = 8;

    /// The i-th preferred successor of (cluster(prev), cur) — a
    /// splitmix hash, so the table never materializes. Conditioning on
    /// the *cluster* of `prev` (not `prev` itself) keeps the structure
    /// compressible: C x V x FANOUT entries instead of V^2 x FANOUT,
    /// which a 10^5-10^6-param model can learn but a capacity-starved
    /// (heavily pruned / coarsely quantized) one cannot hold exactly.
    #[inline]
    fn pair_succ(&self, prev: i32, cur: i32, i: usize) -> i32 {
        let cluster = (prev as u64) % Self::N_CLUSTERS as u64;
        let mut z = self
            .salt
            .wrapping_add(cluster << 32)
            .wrapping_add(cur as u64)
            .wrapping_add((i as u64).wrapping_mul(0xA5A5_5A5A_1234_5678));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (RESERVED as u64 + z % (self.vocab - RESERVED) as u64) as i32
    }

    /// Next token given the (prev, cur) pair.
    pub fn step(&self, prev: i32, cur: i32, rng: &mut Rng) -> i32 {
        if rng.uniform() < CHAIN_MASS {
            let i = rng.categorical(&SUCC_W);
            self.pair_succ(prev, cur, i)
        } else {
            self.zipf(rng)
        }
    }

    /// Sample a sequence of `len` tokens starting after BOS.
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let (mut prev, mut cur) = (TOK_BOS, TOK_BOS);
        for _ in 0..len {
            let next = self.step(prev, cur, rng);
            prev = cur;
            cur = next;
            out.push(next);
        }
        out
    }

    /// Continue a sequence given its last two tokens.
    pub fn continue_from(&self, prev: i32, last: i32, len: usize,
                         rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let (mut p, mut c) = (prev, last);
        for _ in 0..len {
            let next = self.step(p, c, rng);
            p = c;
            c = next;
            out.push(next);
        }
        out
    }
}

/// Training batch stream: [k, b, s+1] token blocks for the scanned
/// train/pretrain artifacts.
pub struct CorpusStream {
    lang: Language,
    rng: Rng,
}

impl CorpusStream {
    pub fn new(lang: &Language, seed: u64) -> CorpusStream {
        CorpusStream { lang: lang.clone(), rng: Rng::new(seed) }
    }

    /// One [k, b, s+1] block, flattened row-major, starting with BOS.
    pub fn next_block(&mut self, k: usize, b: usize, s1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(k * b * s1);
        for _ in 0..k * b {
            out.push(TOK_BOS);
            let seq = self.lang.sample(s1 - 1, &mut self.rng);
            out.extend(seq);
        }
        out
    }
}

/// One multiple-choice item: shared context + `n_choices` continuations.
#[derive(Clone, Debug)]
pub struct EvalItem {
    pub context: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub correct: usize,
}

/// Task family — the knobs that differentiate the seven suites.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_choices: usize,
    pub ctx_len: usize,
    pub choice_len: usize,
    /// fraction of correct-continuation tokens perturbed to build
    /// distractors; lower = harder task
    pub distractor_noise: f64,
    /// fraction of distractors drawn off-chain instead of perturbed
    pub offchain_frac: f64,
    pub seed: u64,
}

/// The seven suites, shaped after the paper's benchmarks: binary
/// yes/no-like tasks (BoolQ, WinoGrande), 4-way continuation tasks at
/// graded difficulty (PIQA, HellaSwag, ARC-e, ARC-c, OBQA).
/// `offchain_frac` = 1.0 means every distractor is a *plausible* chain
/// continuation from a wrong context — only a model that learned the
/// pair-conditioned transitions can reject it.
pub fn paper_suite() -> Vec<TaskSpec> {
    vec![
        TaskSpec { name: "BoolQ", n_choices: 2, ctx_len: 18, choice_len: 4,
                   distractor_noise: 0.6, offchain_frac: 0.5, seed: 101 },
        TaskSpec { name: "PIQA", n_choices: 2, ctx_len: 12, choice_len: 8,
                   distractor_noise: 0.4, offchain_frac: 0.75, seed: 102 },
        TaskSpec { name: "HellaSwag", n_choices: 4, ctx_len: 14, choice_len: 8,
                   distractor_noise: 0.3, offchain_frac: 1.0, seed: 103 },
        TaskSpec { name: "WinoGrande", n_choices: 2, ctx_len: 10, choice_len: 3,
                   distractor_noise: 0.25, offchain_frac: 1.0, seed: 104 },
        TaskSpec { name: "ARC-e", n_choices: 4, ctx_len: 10, choice_len: 6,
                   distractor_noise: 0.55, offchain_frac: 0.5, seed: 105 },
        TaskSpec { name: "ARC-c", n_choices: 4, ctx_len: 10, choice_len: 6,
                   distractor_noise: 0.2, offchain_frac: 1.0, seed: 106 },
        TaskSpec { name: "OBQA", n_choices: 4, ctx_len: 8, choice_len: 5,
                   distractor_noise: 0.35, offchain_frac: 0.75, seed: 107 },
    ]
}

/// Generate `n_items` deterministic items for one task on a language.
pub fn gen_items(lang: &Language, spec: &TaskSpec, n_items: usize)
                 -> Vec<EvalItem> {
    let mut rng = Rng::new(spec.seed ^ lang.style_seed.rotate_left(17));
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        let context = {
            let mut c = vec![TOK_BOS];
            c.extend(lang.sample(spec.ctx_len - 1, &mut rng));
            c
        };
        let n = context.len();
        let (prev, last) = (context[n - 2], context[n - 1]);
        let correct_seq =
            lang.continue_from(prev, last, spec.choice_len, &mut rng);
        let correct = rng.below(spec.n_choices);
        let mut choices = Vec::with_capacity(spec.n_choices);
        for c in 0..spec.n_choices {
            if c == correct {
                choices.push(correct_seq.clone());
            } else if rng.uniform() < spec.offchain_frac {
                // plausible distractor: a true chain continuation from
                // the SAME last token but a wrong hidden `prev` — every
                // token locally follows its predecessor under *some*
                // context, so only a model that learned the
                // pair-conditioned (second-order) transitions can
                // reject it. Capacity lost to pruning/quantization
                // degrades exactly this discrimination.
                let p = loop {
                    let cand =
                        (RESERVED + rng.below(lang.vocab - RESERVED)) as i32;
                    if cand as usize % Language::N_CLUSTERS
                        != prev as usize % Language::N_CLUSTERS
                    {
                        break cand;
                    }
                };
                choices.push(lang.continue_from(p, last, spec.choice_len,
                                                &mut rng));
            } else {
                // perturbed copy of the correct continuation
                let mut d = correct_seq.clone();
                let mut changed = false;
                for t in d.iter_mut() {
                    if rng.uniform() < spec.distractor_noise {
                        *t = (RESERVED + rng.below(lang.vocab - RESERVED))
                            as i32;
                        changed = true;
                    }
                }
                if !changed {
                    let i = rng.below(d.len());
                    d[i] = (RESERVED + rng.below(lang.vocab - RESERVED)) as i32;
                }
                choices.push(d);
            }
        }
        items.push(EvalItem { context, choices, correct });
    }
    items
}

/// Flatten items into evalchoices rows: tokens [R, S] + mask [R, S].
/// Each choice becomes one row: [context..., choice..., pad...].
pub fn pack_rows(items: &[EvalItem], seq: usize)
                 -> (Vec<i32>, Vec<f32>, usize) {
    let n_rows: usize = items.iter().map(|i| i.choices.len()).sum();
    let mut toks = vec![TOK_PAD; n_rows * seq];
    let mut mask = vec![0.0f32; n_rows * seq];
    let mut r = 0;
    for item in items {
        for ch in &item.choices {
            let row_t = &mut toks[r * seq..(r + 1) * seq];
            let row_m = &mut mask[r * seq..(r + 1) * seq];
            let cl = item.context.len().min(seq);
            row_t[..cl].copy_from_slice(&item.context[..cl]);
            let cend = (cl + ch.len()).min(seq);
            row_t[cl..cend].copy_from_slice(&ch[..cend - cl]);
            for m in row_m.iter_mut().take(cend).skip(cl) {
                *m = 1.0;
            }
            r += 1;
        }
    }
    (toks, mask, n_rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn language_is_deterministic() {
        let l1 = Language::new(256, 7);
        let l2 = Language::new(256, 7);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(l1.sample(50, &mut r1), l2.sample(50, &mut r2));
    }

    #[test]
    fn styles_differ() {
        let l1 = Language::new(256, 7);
        let l2 = Language::new(256, 8);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_ne!(l1.sample(50, &mut r1), l2.sample(50, &mut r2));
    }

    #[test]
    fn samples_avoid_reserved_tokens() {
        let lang = Language::new(256, 3);
        let mut rng = Rng::new(2);
        for t in lang.sample(500, &mut rng) {
            assert!(t >= RESERVED as i32 && (t as usize) < 256);
        }
    }

    #[test]
    fn language_has_learnable_structure() {
        // empirical successor distribution of a fixed PAIR must be
        // concentrated (second-order chain)
        let lang = Language::new(256, 5);
        let mut rng = Rng::new(9);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..2000 {
            *counts
                .entry(lang.step(7, 10, &mut rng))
                .or_insert(0usize) += 1;
        }
        let mut v: Vec<usize> = counts.values().cloned().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let top4: usize = v.iter().take(4).sum();
        assert!(top4 as f64 > 0.6 * 2000.0, "top4 mass {top4}/2000");
    }

    #[test]
    fn language_is_second_order() {
        // the successor set of (a, c) must differ from (b, c): the
        // chain is conditioned on the pair, not just the last token
        let lang = Language::new(256, 5);
        let s1: Vec<i32> = (0..4).map(|i| lang.pair_succ(7, 10, i)).collect();
        let s2: Vec<i32> = (0..4).map(|i| lang.pair_succ(8, 10, i)).collect();
        assert_ne!(s1, s2);
        // and deterministic
        let s1b: Vec<i32> = (0..4).map(|i| lang.pair_succ(7, 10, i)).collect();
        assert_eq!(s1, s1b);
    }

    #[test]
    fn corpus_block_shape_and_bos() {
        let lang = Language::new(256, 1);
        let mut cs = CorpusStream::new(&lang, 4);
        let (k, b, s1) = (2, 3, 17);
        let block = cs.next_block(k, b, s1);
        assert_eq!(block.len(), k * b * s1);
        for row in 0..k * b {
            assert_eq!(block[row * s1], TOK_BOS);
        }
    }

    #[test]
    fn corpus_blocks_advance() {
        let lang = Language::new(256, 1);
        let mut cs = CorpusStream::new(&lang, 4);
        let a = cs.next_block(1, 1, 16);
        let b = cs.next_block(1, 1, 16);
        assert_ne!(a, b);
    }

    #[test]
    fn paper_suite_has_seven_distinct_tasks() {
        let suite = paper_suite();
        assert_eq!(suite.len(), 7);
        let mut names: Vec<&str> = suite.iter().map(|t| t.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 7);
    }

    #[test]
    fn items_have_valid_structure() {
        let lang = Language::new(256, 2);
        for spec in paper_suite() {
            let items = gen_items(&lang, &spec, 10);
            assert_eq!(items.len(), 10);
            for it in &items {
                assert_eq!(it.choices.len(), spec.n_choices);
                assert!(it.correct < spec.n_choices);
                assert_eq!(it.context.len(), spec.ctx_len);
                for c in &it.choices {
                    assert_eq!(c.len(), spec.choice_len);
                }
                // distractors differ from the correct choice
                let correct = &it.choices[it.correct];
                for (i, c) in it.choices.iter().enumerate() {
                    if i != it.correct {
                        assert_ne!(c, correct, "identical distractor");
                    }
                }
            }
        }
    }

    #[test]
    fn items_deterministic_per_seed() {
        let lang = Language::new(256, 2);
        let spec = &paper_suite()[0];
        let a = gen_items(&lang, spec, 5);
        let b = gen_items(&lang, spec, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn pack_rows_layout() {
        let lang = Language::new(256, 2);
        let spec = &paper_suite()[3]; // WinoGrande-like, 2 choices
        let items = gen_items(&lang, spec, 3);
        let seq = 32;
        let (toks, mask, rows) = pack_rows(&items, seq);
        assert_eq!(rows, 6);
        assert_eq!(toks.len(), rows * seq);
        for r in 0..rows {
            let row_m = &mask[r * seq..(r + 1) * seq];
            let scored: f32 = row_m.iter().sum();
            assert_eq!(scored as usize, spec.choice_len);
            // mask must be contiguous after the context
            let first = row_m.iter().position(|&m| m > 0.0).unwrap();
            assert_eq!(first, spec.ctx_len);
        }
    }
}
