//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute
//! from the rust hot path. Python never runs here.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos; the text parser reassigns instruction ids).
//! Every artifact was lowered with `return_tuple=True`, so execution
//! returns a single tuple literal that we decompose positionally.
//!
//! The `xla` PJRT bindings are not vendored in this offline build, so
//! this module links against `crate::xla_stub` — a drop-in API subset
//! whose Literal marshaling is fully functional and whose
//! compile/execute paths report a clear "backend not linked" error.
//! Callers either skip when `has_artifact` is false (tests, benches) or
//! fall back to a native path (the `serve` engine). Swapping the `use`
//! below for the real crate restores AOT execution unchanged.

use crate::tensor::Tensor;
use crate::xla_stub as xla;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Reinterpret a slice of plain scalar values as its little-endian byte
/// representation for literal marshaling.
///
/// SAFETY invariant (callers must uphold): `T` is a plain-old-data
/// scalar with no padding and no invalid bit patterns (`f32`, `i32`,
/// `i8`, `u8` here). The returned slice covers exactly
/// `size_of_val(data)` bytes of the same allocation, `u8` has alignment
/// 1 so any source alignment is valid, and the borrow ties the slice's
/// lifetime to `data`, so the pointer cannot dangle.
fn pod_bytes<T: Copy>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            std::mem::size_of_val(data),
        )
    }
}

/// Typed host-side value crossing the PJRT boundary.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], &'a [usize]),
    U8(&'a [u8], &'a [usize]),
    I8(&'a [i8], &'a [usize]),
    Scalar(f32),
}

impl Arg<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, shape, bytes): (_, &[usize], &[u8]) = match self {
            Arg::F32(t) => return lit_f32(t),
            Arg::Scalar(v) => return Ok(xla::Literal::scalar(*v)),
            Arg::I32(data, shape) => {
                (xla::ElementType::S32, *shape, pod_bytes(*data))
            }
            Arg::U8(data, shape) => {
                (xla::ElementType::U8, *shape, pod_bytes(*data))
            }
            Arg::I8(data, shape) => {
                (xla::ElementType::S8, *shape, pod_bytes(*data))
            }
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty, shape, bytes,
        )?)
    }
}

/// f32 Tensor -> Literal.
pub fn lit_f32(t: &Tensor) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        pod_bytes(t.data()),
    )?)
}

/// Literal -> f32 Tensor (copies out).
pub fn tensor_f32(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(&dims, data))
}

/// Manifest entry (written by aot.py).
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

/// The runtime: PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: HashMap<String, ManifestEntry>,
    /// executions per artifact (metrics)
    pub exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Open the artifact directory (default: ./artifacts).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let mut manifest = HashMap::new();
        let mpath = dir.join("manifest.tsv");
        if mpath.exists() {
            for line in std::fs::read_to_string(&mpath)?.lines() {
                let parts: Vec<&str> = line.split('\t').collect();
                if parts.len() >= 3 {
                    manifest.insert(
                        parts[0].to_string(),
                        ManifestEntry {
                            name: parts[0].to_string(),
                            n_inputs: parts[1].parse().unwrap_or(0),
                            n_outputs: parts[2].parse().unwrap_or(0),
                        },
                    );
                }
            }
        }
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            cache: HashMap::new(),
            manifest,
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact location relative to the repo root, overridable
    /// via QPRUNER_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        std::env::var("QPRUNER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn open_default() -> Result<Runtime> {
        Runtime::new(&Self::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    pub fn manifest_entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.manifest.get(name)
    }

    /// Compile (or fetch from cache) an artifact by logical name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {name} not found at {path:?} — run `make artifacts`"
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with typed args; returns all outputs as
    /// decomposed literals.
    pub fn exec(&mut self, name: &str, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        if let Some(me) = self.manifest.get(name) {
            if me.n_inputs != args.len() {
                bail!(
                    "{name}: manifest expects {} inputs, got {}",
                    me.n_inputs,
                    args.len()
                );
            }
        }
        self.load(name)?;
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&lits)?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let mut root = result[0][0].to_literal_sync()?;
        Ok(root.decompose_tuple()?)
    }

    /// Execute and return all outputs converted to f32 tensors.
    pub fn exec_f32(&mut self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        self.exec(name, args)?.iter().map(tensor_f32).collect()
    }

    // NOTE(§Perf): a resident-buffer execute_b path (upload frozen
    // weights once, reuse PjRtBuffers across calls) was implemented and
    // reverted: the tfrt CPU PJRT client consumes/donates input buffers
    // on execute, so cross-call reuse aborts (`literal.size_bytes() ==
    // b->size()` checks / segfaults). Literal-per-call is the sound
    // fast path on this client; see EXPERIMENTS.md §Perf entry 3.

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = lit_f32(&t).unwrap();
        let back = tensor_f32(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn arg_i32_builds_literal() {
        let data = [1i32, 2, 3, 4];
        let lit = Arg::I32(&data, &[2, 2]).to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn arg_u8_builds_literal() {
        let data = [7u8, 8, 9, 10];
        let lit = Arg::U8(&data, &[4]).to_literal().unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), vec![7, 8, 9, 10]);
    }

    #[test]
    fn missing_artifact_errors() {
        let dir = std::env::temp_dir().join("qpruner_rt_empty");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        assert!(rt.exec("nope", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
