//! Offline stand-in for the `anyhow` crate, API-compatible with the
//! subset this workspace uses: `Result<T>`, `Error`, the `anyhow!` /
//! `bail!` / `ensure!` macros, and the `Context` extension trait on
//! `Result` and `Option`.
//!
//! Rationale: the build environment vendors no registry crates, so a
//! `cargo build` that referenced crates.io `anyhow` could never
//! resolve. This shim keeps the whole dependency graph in-tree. Like
//! the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

use std::fmt;

/// Dynamic error: a message plus the `context(..)` frames wrapped
/// around it, rendered outermost-first like `anyhow`'s `{:#}` chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
        let n: Result<i32> = "12".parse::<i32>().context("parse");
        assert_eq!(n.unwrap(), 12);
        let bad: Result<i32> = "xy".parse::<i32>().context("parse");
        let msg = format!("{}", bad.unwrap_err());
        assert!(msg.starts_with("parse: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(3u8).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn ensure_bare_condition() {
        fn g(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(g(true).is_ok());
        assert!(format!("{}", g(false).unwrap_err()).contains("ok"));
    }
}
