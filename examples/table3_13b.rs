//! Regenerate Table 3 (13B scale, 50 % pruning): LLM-Pruner vs
//! QPruner^1 vs QPruner^3 with the 13B-architecture memory model.
//!
//!   cargo run --release --example table3_13b -- [size] [smoke|paper]

use anyhow::Result;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let scale = match args.get(1).map(|s| s.as_str()) {
        Some("paper") => Scale::paper(),
        _ => Scale::smoke(),
    };
    let cfg = ModelConfig::preset(size)?;
    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        scale.pretrain_steps)?;
    let t = experiments::table3_13b(&mut coord, &store, &scale)?;
    t.save(Path::new("results"), "table3")?;
    println!("{}", t.to_markdown());
    println!("saved to results/table3.{{md,csv}}");
    Ok(())
}
