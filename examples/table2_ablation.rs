//! Regenerate Table 2 (ablations at 20 % pruning): 4-bit data type
//! (NF4/FP4), adapter initialization (LoftQ/Gaussian/PiSSA), LoftQ
//! iteration count (1/2/4) and importance estimation order
//! (element^1/element^2).
//!
//!   cargo run --release --example table2_ablation -- [size] [smoke|paper]

use anyhow::Result;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let scale = match args.get(1).map(|s| s.as_str()) {
        Some("paper") => Scale::paper(),
        _ => Scale::smoke(),
    };
    let cfg = ModelConfig::preset(size)?;
    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        scale.pretrain_steps)?;
    let t = experiments::table2_ablation(&mut coord, &store, &scale)?;
    t.save(Path::new("results"), "table2")?;
    println!("{}", t.to_markdown());
    println!("saved to results/table2.{{md,csv}}");
    Ok(())
}
