//! Regenerate Figures 3/4: Bayesian-optimization Pareto-front scatter
//! plots per task (memory vs accuracy; front points flagged).
//!
//!   cargo run --release --example fig3_pareto -- [size] [points] [init] [rate]
//!
//! Defaults: small 18 6 50 (the paper used 50 points = 10 init + 40 BO
//! iterations at 50 % pruning; run `small 50 10 50` to match).

use anyhow::Result;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use qpruner::report::scatter_csv;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let points: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(18);
    let init: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    let rate: u32 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(50);

    let cfg = ModelConfig::preset(size)?;
    let scale = Scale::smoke();
    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        Scale::paper().pretrain_steps)?;

    let data = experiments::fig3_pareto(&mut coord, &store, rate, points,
                                        init, &scale)?;
    std::fs::create_dir_all("results")?;
    for (task, rows) in &data.per_task {
        let pts: Vec<(f64, f64, String)> = rows
            .iter()
            .map(|(m, p, c, front)| {
                (*m, *p, format!("{c}{}", if *front { ":front" } else { "" }))
            })
            .collect();
        let path = format!("results/fig3_{}.csv", task.to_lowercase());
        std::fs::write(&path, scatter_csv(&pts))?;
        let front: Vec<&(f64, f64, String, bool)> =
            rows.iter().filter(|r| r.3).collect();
        println!("{task}: {} points, Pareto front:", rows.len());
        for (m, p, c, _) in front {
            println!("    {:.2} GB  {:.1}%  bits={}", m, 100.0 * p, c);
        }
    }
    println!("({} evaluations; scatter CSVs in results/)", data.n_evals);
    Ok(())
}
