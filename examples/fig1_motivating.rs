//! Regenerate Figure 1 (motivating example): accuracy and memory for
//! LoRA-fp16, LoftQ uniform 4-bit, and LoftQ* mixed 4/8-bit at 20 %
//! pruning.
//!
//!   cargo run --release --example fig1_motivating -- [size] [smoke|paper]

use anyhow::Result;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let scale = match args.get(1).map(|s| s.as_str()) {
        Some("paper") => Scale::paper(),
        _ => Scale::smoke(),
    };
    let cfg = ModelConfig::preset(size)?;
    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        scale.pretrain_steps)?;
    let t = experiments::fig1_motivating(&mut coord, &store, &scale)?;
    t.save(Path::new("results"), "fig1")?;
    println!("{}", t.to_markdown());
    println!("saved to results/fig1.{{md,csv}}");
    Ok(())
}
