//! End-to-end validation driver (DESIGN.md §7).
//!
//! Exercises every layer on a real workload: pretrains (or loads) the
//! `base` 15.7M-param transformer on the synthetic corpus, runs the
//! full QPruner^3 pipeline at 20 % pruning with a real recovery
//! fine-tune of several hundred LoRA steps through the AOT train-step
//! executable, logs the loss curve to results/e2e_loss.csv, and reports
//! the 7-task zero-shot accuracy plus paper-scale memory.
//!
//!   cargo run --release --example e2e_train -- [size] [ft_steps] [pretrain_steps]
//!
//! Defaults: base 240 800. Use `small 120 400` for a faster pass.

use anyhow::Result;
use qpruner::coordinator::{Method, PipelineOpts};
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("base");
    let ft_steps: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(240);
    let pretrain_steps: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(800);

    let cfg = ModelConfig::preset(size)?;
    println!(
        "e2e: {} ({} params), {} pretrain steps, {} fine-tune steps",
        cfg.name,
        cfg.param_count(&cfg.pruned(0)),
        pretrain_steps,
        ft_steps
    );

    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let t0 = std::time::Instant::now();
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        pretrain_steps)?;
    println!("checkpoint ready in {:.1}s", t0.elapsed().as_secs_f64());

    let mut opts = PipelineOpts::quick(20, Method::QPruner3);
    Scale::paper().apply(&mut opts);
    opts.recover.finetune.steps = ft_steps;
    opts.eval_items = 60;
    opts.bo.iters = 4;
    opts.bo.init_random = 2;
    opts.bo.proxy_steps = 12;
    opts.bo.proxy_items = 10;

    let t1 = std::time::Instant::now();
    let res = coord.run(&store, &opts)?;
    let wall = t1.elapsed().as_secs_f64();

    std::fs::create_dir_all("results")?;
    res.curve.save_csv(Path::new("results/e2e_loss.csv"))?;

    println!("\n=== e2e results ({}, QPruner^3 @20%) ===", cfg.name);
    println!("bit config   : {}", res.bits.short());
    println!("BO evals     : {}", res.observations.len());
    println!(
        "loss curve   : {:.3} -> {:.3} ({} steps, results/e2e_loss.csv)",
        res.curve.losses.first().copied().unwrap_or(f32::NAN),
        res.curve.tail_mean(16),
        res.curve.losses.len()
    );
    for t in &res.tasks {
        println!("  {:<12} {:.2}%", t.name, 100.0 * t.accuracy);
    }
    println!("mean accuracy: {:.2}%", 100.0 * res.mean_accuracy);
    println!("memory (GB)  : {:.2} (paper-scale 7B)", res.memory_gb);
    println!("pipeline wall: {wall:.1}s");
    println!("\nstage timings:\n{}", coord.metrics.report());
    Ok(())
}
