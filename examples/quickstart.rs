//! Quickstart: the whole QPruner pipeline on the tiny preset in ~1 min.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Pretrains a tiny corpus checkpoint, prunes 20 % of it by Taylor
//! group importance, allocates mixed-precision bit-widths from mutual
//! information, refines them with Bayesian optimization, LoftQ-
//! initializes the adapters, recovery-fine-tunes, and evaluates on the
//! 7-task synthetic suite — reporting paper-scale memory next to each
//! configuration.

use anyhow::Result;
use qpruner::coordinator::{Coordinator, Method, PipelineOpts};
use qpruner::data::Language;
use qpruner::model::ModelConfig;
use qpruner::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    let lang = Language::new(256, 1);
    let mut coord = Coordinator::new(rt, lang);

    // 1. the "public checkpoint" stand-in: pretrain on the corpus
    let cfg = ModelConfig::preset("tiny")?;
    println!("pretraining {} ({} params)...", cfg.name,
             cfg.param_count(&cfg.pruned(0)));
    let (store, curve) = coord.pretrain(&cfg, 96, 3e-3, 42)?;
    println!("  loss {:.3} -> {:.3}", curve.losses[0], curve.tail_mean(8));

    // 2-5. the QPruner pipeline at 20% pruning
    for method in [Method::LlmPruner, Method::QPruner1, Method::QPruner2,
                   Method::QPruner3] {
        let mut opts = PipelineOpts::quick(20, method);
        opts.recover.finetune.steps = 24;
        opts.eval_items = 25;
        opts.bo.iters = 3;
        opts.bo.init_random = 2;
        opts.bo.proxy_steps = 8;
        opts.bo.proxy_items = 10;
        let res = coord.run(&store, &opts)?;
        println!(
            "{:<12} bits={} mean-acc={:.2}% mem={:.2}GB (trainable {})",
            res.method.label(),
            res.bits.short(),
            100.0 * res.mean_accuracy,
            res.memory_gb,
            res.trainable_params,
        );
        for t in &res.tasks {
            print!("  {}={:.0}%", t.name, 100.0 * t.accuracy);
        }
        println!();
    }
    println!("\nstage timings:\n{}", coord.metrics.report());
    Ok(())
}
