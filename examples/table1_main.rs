//! Regenerate Table 1 (main results): two corpus dialects (LLaMA /
//! Vicuna stand-ins) x pruning rates {20, 30, 50} x methods
//! {LLM-Pruner, QPruner^1, QPruner^2, QPruner^3} on the 7-task suite,
//! with paper-scale peak-memory accounting.
//!
//!   cargo run --release --example table1_main -- [size] [smoke|paper]
//!
//! Defaults: small smoke (minutes). The recorded EXPERIMENTS.md run
//! used `small paper`.

use anyhow::Result;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let scale = match args.get(1).map(|s| s.as_str()) {
        Some("paper") => Scale::paper(),
        _ => Scale::smoke(),
    };
    let cfg = ModelConfig::preset(size)?;
    let ckpt = Path::new("checkpoints");

    let mut table = None;
    for (label, style) in [("7B-sim", "llama"), ("7B-chat-sim", "vicuna")] {
        let mut coord = experiments::open_coordinator(cfg.vocab, style)?;
        let store = experiments::load_or_pretrain(
            &mut coord, &cfg, ckpt, style, scale.pretrain_steps)?;
        let t = experiments::table1(&mut coord, &[(label, &store)],
                                    &[20, 30, 50], &scale)?;
        match &mut table {
            None => table = Some(t),
            Some(acc) => acc.rows.extend(t.rows),
        }
    }
    let table = table.unwrap();
    table.save(Path::new("results"), "table1")?;
    println!("{}", table.to_markdown());
    println!("saved to results/table1.{{md,csv}}");
    Ok(())
}
