//! Ablation: what does importance-driven selection actually buy?
//!
//! Compares three pruning plans at the same rate — Taylor-importance
//! (the paper's §3.1), first-k (structural control) and random — each
//! followed by the same quantize + LoftQ + recovery fine-tune + eval
//! protocol, and prints the layer-pruning profile that motivates the
//! paper's mixed-precision allocation (uneven layer importance).
//!
//!   cargo run --release --example ablation_pruning -- [size] [rate]

use anyhow::Result;
use qpruner::coordinator::{Method, PipelineOpts};
use qpruner::data::CorpusStream;
use qpruner::eval::{eval_suite, mean_accuracy};
use qpruner::experiments::{self, Scale};
use qpruner::finetune::{self, FinetuneOpts, FinetuneState};
use qpruner::lora::{self, LoraState};
use qpruner::model::ModelConfig;
use qpruner::pruning::{self, Aggregate, DependencyGraph, PruningPlan,
                       TaylorOrder};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::report::{pct, Table};
use qpruner::rng::Rng;
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small");
    let rate: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let cfg = ModelConfig::preset(size)?;
    let scale = Scale::smoke();

    let mut coord = experiments::open_coordinator(cfg.vocab, "llama")?;
    let store = experiments::load_or_pretrain(
        &mut coord, &cfg, Path::new("checkpoints"), "llama",
        Scale::paper().pretrain_steps)?;

    // shared importance pass
    let graph = DependencyGraph::build(&cfg);
    let zero = LoraState::zeros(&store);
    let mut stream = CorpusStream::new(&coord.lang, 0xAB1A);
    let toks = stream.next_block(1, cfg.batch, cfg.seq + 1);
    let (_, grads) =
        finetune::weight_grads(&mut coord.rt, &store, &zero, &toks)?;
    let imp = pruning::group_importance(&cfg, &graph, &store, &grads,
                                        TaylorOrder::First, Aggregate::Sum)?;

    // the uneven-layer-importance profile (the paper's §1 motivation)
    let profile = pruning::layer_pruning_profile(&cfg, &graph, &imp, rate);
    println!("global-ranking pruning profile at {rate}% (groups lost per \
              layer): {profile:?}\n");

    let plans: Vec<(&str, PruningPlan)> = vec![
        ("taylor", PruningPlan::from_importance(&cfg, &graph, &imp, rate)),
        ("first-k", PruningPlan::first_k(&cfg, rate)),
        ("random", PruningPlan::random(&cfg, rate, &mut Rng::new(7))),
    ];

    let mut t = Table::new(
        &format!("Pruning-strategy ablation @ {rate}% ({})", cfg.name),
        &["Plan", "Overlap w/ taylor", "Mean acc (%)"],
    );
    let taylor_plan = plans[0].1.clone();
    for (name, plan) in plans {
        let pruned = pruning::apply_plan(&store, &plan)?;
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let mut rng = Rng::new(11);
        let prep = lora::prepare(&pruned, &bits,
                                 qpruner::lora::InitMethod::LoftQ { iters: 1 },
                                 &mut rng)?;
        let mut state = FinetuneState::new(prep.lora);
        let mut s2 = CorpusStream::new(&coord.lang, 0xF00D);
        let ft = FinetuneOpts {
            steps: scale.finetune_steps * 3,
            lr: 3e-4,
            warmup: 4,
            seed: 1,
        };
        finetune::finetune(&mut coord.rt, &prep.base, &mut state, &mut s2,
                           &ft)?;
        let results = eval_suite(&mut coord.rt, &prep.base, &state.lora,
                                 &coord.lang, &qpruner::data::paper_suite(),
                                 40)?;
        t.push_row(vec![
            name.to_string(),
            format!("{:.2}", plan.overlap(&taylor_plan)),
            pct(mean_accuracy(&results)),
        ]);
        let _ = PipelineOpts::quick(rate, Method::QPruner1);
    }
    println!("{}", t.to_markdown());
    Ok(())
}
